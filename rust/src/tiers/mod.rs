//! Storage tiers for real mode: directory-backed stores with capacity
//! accounting and optional performance shaping.
//!
//! A [`Tier`] maps logical Sea paths to physical paths under its root
//! directory and tracks used bytes with lock-free reservation. Performance
//! shaping makes a plain directory behave like the paper's storage devices
//! without the hardware:
//!
//! * [`Throttle`] — a token bucket capping data bandwidth (a degraded
//!   Lustre OST pool under busy writers);
//! * per-op metadata latency (a loaded Lustre MDS).
//!
//! Shaping is *honest waiting*: callers really block, so real-mode
//! experiments measure true elapsed time.
//!
//! ## Two-class throttle protocol
//!
//! A shaped tier's bandwidth budget is shared by two kinds of traffic with
//! very different urgency: *foreground* (the application blocked inside an
//! intercepted `read`/`write`, or the flusher persisting dirty bytes the
//! application is waiting on) and *background* (prefetch staging, bulk
//! tier-to-tier transfer). The raw token bucket is therefore wrapped in a
//! [`crate::sched::QosThrottle`]: every acquisition names an
//! [`crate::sched::IoClass`], foreground waits charge a *debt* counter,
//! and background acquisitions yield in bounded slices while foreground
//! waiters are live or debt is unpaid (capped ≈250 ms so background never
//! starves outright). [`Tier::wait_data`] is the foreground entry point —
//! all pre-existing call sites keep their behaviour — and
//! [`Tier::wait_data_class`] is what the transfer engine routes through
//! with an explicit class. The split is toggled at mount via
//! [`Tier::set_qos`] (config `[sched] qos`); disabled, both classes
//! collapse to the old single-queue bucket. All QoS state is lock-free
//! atomics around the bucket's own mutex, so the protocol adds no lock
//! ordering edges: throttles remain self-contained leaves that may be
//! waited on under any higher-level lock.

pub mod throttle;

pub use throttle::Throttle;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use crate::config::CacheDef;
use crate::sched::{IoClass, QosSnapshot, QosThrottle};

/// Index of a tier within a [`TierSet`]: caches first (0 = fastest),
/// persistent store last.
pub type TierIdx = usize;

/// One directory-backed storage tier.
#[derive(Debug)]
pub struct Tier {
    pub name: String,
    root: PathBuf,
    capacity: u64,
    used: AtomicU64,
    data_throttle: Option<QosThrottle>,
    meta_latency: Option<Duration>,
    /// Dropout flag: a down tier refuses transfers at [`Tier::check_up`]
    /// call sites. Set at mount from an armed `FaultPlan`
    /// (`tier.<name>=down`), or toggled mid-run by chaos tests; the
    /// health engine (`crate::health`) watches it through its prober and
    /// converts the resulting failures into degraded-mode operation.
    down: AtomicBool,
}

impl Tier {
    pub fn new(def: &CacheDef) -> std::io::Result<Tier> {
        std::fs::create_dir_all(&def.root)?;
        Ok(Tier {
            name: def.name.clone(),
            root: def.root.clone(),
            capacity: def.capacity,
            used: AtomicU64::new(0),
            data_throttle: None,
            meta_latency: None,
            down: AtomicBool::new(false),
        })
    }

    /// Cap data bandwidth (bytes/s) through this tier. The burst window is
    /// 50 ms so even sub-second experiments see the cap.
    ///
    /// Panics on a non-positive/non-finite rate — programmatic builder for
    /// tests and benches; config-driven paths validate first via
    /// [`Throttle::with_burst`].
    pub fn with_bandwidth_limit(mut self, bytes_per_sec: f64) -> Tier {
        let bucket = Throttle::with_burst(bytes_per_sec, 0.05)
            .expect("tier bandwidth limit must be finite and > 0");
        self.data_throttle = Some(QosThrottle::new(bucket));
        self
    }

    /// Add fixed latency to every metadata operation on this tier.
    pub fn with_meta_latency(mut self, latency: Duration) -> Tier {
        self.meta_latency = Some(latency);
        self
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used())
    }

    /// Physical path of a logical Sea path (which is always absolute,
    /// e.g. `/sub-01/func/bold.nii`).
    pub fn physical(&self, logical: &str) -> PathBuf {
        debug_assert!(logical.starts_with('/'), "logical path must be absolute");
        self.root.join(logical.trim_start_matches('/'))
    }

    /// Try to account for `bytes` more; false if the tier would overflow.
    pub fn try_reserve(&self, bytes: u64) -> bool {
        let mut cur = self.used.load(Ordering::Relaxed);
        loop {
            let next = match cur.checked_add(bytes) {
                Some(n) if n <= self.capacity => n,
                _ => return false,
            };
            match self.used.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(actual) => cur = actual,
            }
        }
    }

    pub fn release(&self, bytes: u64) {
        let mut cur = self.used.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.used.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Block for the tier's data-bandwidth budget before moving `bytes`
    /// as foreground (application-blocking) traffic.
    pub fn wait_data(&self, bytes: u64) {
        self.wait_data_class(bytes, IoClass::Foreground);
    }

    /// Block for the tier's data-bandwidth budget before moving `bytes`
    /// under an explicit bandwidth class (see the module docs for the
    /// two-class protocol).
    pub fn wait_data_class(&self, bytes: u64, class: IoClass) {
        if let Some(t) = &self.data_throttle {
            t.acquire(bytes, class);
        }
    }

    /// Enable/disable the foreground/background class split on this
    /// tier's throttle (config `[sched] qos`); no-op on unshaped tiers.
    pub fn set_qos(&self, on: bool) {
        if let Some(t) = &self.data_throttle {
            t.set_enabled(on);
        }
    }

    /// [`Tier::wait_data_class`] with a tenant tag: background traffic
    /// draws the tenant's QoS lane bucket first (when lanes are
    /// installed), and the returned yield count feeds per-tenant
    /// throttle accounting. No-op (returns 0) on unshaped tiers.
    pub fn wait_data_tagged(&self, bytes: u64, class: IoClass, tenant: u16) -> u32 {
        match &self.data_throttle {
            Some(t) => t.acquire_tagged(bytes, class, tenant),
            None => 0,
        }
    }

    /// Install per-tenant background token-bucket lanes on this tier's
    /// throttle (multi-tenant mounts only; see
    /// [`crate::sched::QosThrottle::set_tenant_lanes`]).
    pub fn set_tenant_lanes(&self, n_tenants: usize) {
        if let Some(t) = &self.data_throttle {
            t.set_tenant_lanes(n_tenants);
        }
    }

    /// Enable adaptive QoS debt decay (`[sched] qos_adaptive`).
    pub fn set_qos_adaptive(&self, on: bool) {
        if let Some(t) = &self.data_throttle {
            t.set_adaptive(on);
        }
    }

    /// Feed a measured bandwidth observation (bytes/s) into the
    /// throttle's adaptive decay; no-op on unshaped tiers.
    pub fn set_measured_rate(&self, bytes_per_sec: f64) {
        if let Some(t) = &self.data_throttle {
            t.set_measured_rate(bytes_per_sec);
        }
    }

    /// Per-tenant background lane counters `(bg_bytes, yields)`, when
    /// this tier is shaped and lanes are installed.
    pub fn lane_snapshot(&self, tenant: u16) -> Option<(u64, u64)> {
        self.data_throttle.as_ref().and_then(|t| t.lane_snapshot(tenant))
    }

    /// Per-class bandwidth counters, when this tier is shaped.
    pub fn qos_snapshot(&self) -> Option<QosSnapshot> {
        self.data_throttle.as_ref().map(|t| t.snapshot())
    }

    /// Block for one metadata operation (open/create/stat/unlink/rename).
    pub fn wait_meta(&self) {
        if let Some(d) = self.meta_latency {
            std::thread::sleep(d);
        }
    }

    pub fn is_throttled(&self) -> bool {
        self.data_throttle.is_some() || self.meta_latency.is_some()
    }

    /// True when the tier has a data-bandwidth throttle (the adaptive
    /// QoS prober only measures shaped tiers — an unshaped tier has no
    /// debt to decay).
    pub fn is_data_shaped(&self) -> bool {
        self.data_throttle.is_some()
    }

    /// Mark the tier dropped out (or back up) — fault injection: set at
    /// mount from the armed `FaultPlan`, or flipped mid-run by chaos
    /// tests simulating a device that dies and recovers.
    pub fn set_down(&self, down: bool) {
        self.down.store(down, Ordering::Relaxed);
    }

    pub fn is_down(&self) -> bool {
        self.down.load(Ordering::Relaxed)
    }

    /// Refuse the operation if the tier is dropped out. Transfer
    /// endpoints check both sides before moving bytes, so a dead tier
    /// fails copies loudly instead of half-writing into it.
    pub fn check_up(&self) -> std::io::Result<()> {
        if self.is_down() {
            Err(std::io::Error::other(format!("tier {} is down", self.name)))
        } else {
            Ok(())
        }
    }
}

/// The ordered set of tiers Sea redirects across: caches fastest-first,
/// persistent store last (mirrors `sea.ini` declaration order).
#[derive(Debug)]
pub struct TierSet {
    tiers: Vec<Tier>,
    /// Index of the persistent tier (always `tiers.len() - 1`).
    persist: TierIdx,
}

impl TierSet {
    /// Build from cache defs + the persistent def. The persistent tier may
    /// be shaped by `shape_persist` (e.g. throttled to emulate degraded
    /// Lustre).
    pub fn new(
        caches: &[CacheDef],
        persist_def: &CacheDef,
        shape_persist: impl FnOnce(Tier) -> Tier,
    ) -> std::io::Result<TierSet> {
        let mut tiers = caches.iter().map(Tier::new).collect::<Result<Vec<_>, _>>()?;
        tiers.push(shape_persist(Tier::new(persist_def)?));
        Ok(TierSet {
            persist: tiers.len() - 1,
            tiers,
        })
    }

    pub fn len(&self) -> usize {
        self.tiers.len()
    }

    pub fn is_empty(&self) -> bool {
        false // always at least the persistent tier
    }

    pub fn get(&self, idx: TierIdx) -> &Tier {
        &self.tiers[idx]
    }

    pub fn persist_idx(&self) -> TierIdx {
        self.persist
    }

    pub fn persist(&self) -> &Tier {
        &self.tiers[self.persist]
    }

    /// Cache tiers in priority order (excludes the persistent tier).
    pub fn caches(&self) -> &[Tier] {
        &self.tiers[..self.persist]
    }

    /// First tier (fastest-first) that can take `bytes` more; falls back to
    /// the persistent tier, which always accepts (matching the paper: when
    /// caches fill, writes go to Lustre).
    ///
    /// A zero-byte request (new-file placement before any data is written)
    /// skips caches with no free bytes: `try_reserve(0)` would "succeed"
    /// even on a completely full cache, and the first real write would
    /// then be forced into a guaranteed whole-file spill.
    ///
    /// The persistent tier's capacity is **never reserved** (shared FS
    /// quota is not Sea's concern; the paper's quota argument is about
    /// file *counts*): nothing releases persist bytes on unlink or
    /// failed spill, so a reservation here would only drift `used()`
    /// monotonically upward. Persist-resident bytes for reporting come
    /// from the namespace (`Namespace::bytes_on_tier`) instead.
    pub fn place_write(&self, bytes: u64) -> TierIdx {
        self.place_write_filtered(bytes, |_| true)
    }

    /// [`TierSet::place_write`] restricted to caches the predicate
    /// accepts — the health engine's degraded-mode entry point: a `Down`
    /// or `Full` tier is filtered out so new replicas land on healthy
    /// tiers (or persist, which is never filtered: it is the durability
    /// root and has no healthy alternative).
    pub fn place_write_filtered(
        &self,
        bytes: u64,
        usable: impl Fn(TierIdx) -> bool,
    ) -> TierIdx {
        for (idx, tier) in self.tiers[..self.persist].iter().enumerate() {
            if !usable(idx) {
                continue;
            }
            if bytes == 0 {
                if tier.free() > 0 {
                    return idx;
                }
            } else if tier.try_reserve(bytes) {
                return idx;
            }
        }
        self.persist
    }

    /// Fastest tier among `candidates` (smallest index).
    pub fn fastest_of(&self, candidates: impl IntoIterator<Item = TierIdx>) -> Option<TierIdx> {
        candidates.into_iter().min()
    }

    /// Reserve `bytes` on the fastest *cache* with room and hand the
    /// reservation to the caller (the transfer engine's staging path).
    /// `None` when no cache can hold them — unlike
    /// [`TierSet::place_write`], the persistent tier is never a staging
    /// target, so there is no fallthrough.
    ///
    /// This is the capacity-only primitive: it cannot make room, because
    /// the tier set knows nothing about which replicas are cold or
    /// clean. The evict-to-make-room admission path lives one layer up
    /// in `SeaCore::reserve_on_cache_evicting`, which drains cold clean
    /// replicas (ranked by the configured eviction policy — GDSF
    /// cost-aware by default, see [`crate::sched`] — fence-skipping)
    /// and then retries this reservation.
    pub fn reserve_on_cache(&self, bytes: u64) -> Option<TierIdx> {
        self.reserve_on_cache_filtered(bytes, |_| true)
    }

    /// [`TierSet::reserve_on_cache`] restricted to caches the predicate
    /// accepts (see [`TierSet::place_write_filtered`]). `None` when no
    /// healthy cache can hold the bytes.
    pub fn reserve_on_cache_filtered(
        &self,
        bytes: u64,
        usable: impl Fn(TierIdx) -> bool,
    ) -> Option<TierIdx> {
        self.caches()
            .iter()
            .enumerate()
            .position(|(idx, tier)| usable(idx) && tier.try_reserve(bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CacheDef;
    use crate::util::MIB;

    use crate::testing::tempdir;

    fn tmp(name: &str) -> (tempdir::TempDirGuard, CacheDef) {
        let dir = tempdir::tempdir(name);
        let def = CacheDef {
            name: name.to_string(),
            root: dir.path().to_path_buf(),
            capacity: MIB,
        };
        (dir, def)
    }

    #[test]
    fn physical_paths_nest_under_root() {
        let (_g, def) = tmp("phys");
        let tier = Tier::new(&def).unwrap();
        let p = tier.physical("/sub-01/func/bold.nii");
        assert!(p.starts_with(tier.root()));
        assert!(p.ends_with("sub-01/func/bold.nii"));
    }

    #[test]
    fn reserve_respects_capacity() {
        let (_g, def) = tmp("cap");
        let tier = Tier::new(&def).unwrap();
        assert!(tier.try_reserve(MIB / 2));
        assert!(tier.try_reserve(MIB / 2));
        assert!(!tier.try_reserve(1));
        tier.release(MIB / 2);
        assert!(tier.try_reserve(MIB / 4));
        assert_eq!(tier.free(), MIB / 4);
    }

    #[test]
    fn release_saturates_at_zero() {
        let (_g, def) = tmp("rel");
        let tier = Tier::new(&def).unwrap();
        tier.release(12345);
        assert_eq!(tier.used(), 0);
    }

    #[test]
    fn place_write_prefers_fastest_with_space() {
        let (_g1, fast) = tmp("fast");
        let (_g2, slow) = tmp("slow");
        let (_g3, lus) = tmp("lus");
        let ts = TierSet::new(&[fast, slow], &lus, |t| t).unwrap();
        // Fill the fast tier
        assert_eq!(ts.place_write(MIB), 0);
        // Fast is full now; next goes to the second cache
        assert_eq!(ts.place_write(MIB), 1);
        // Both caches full: falls through to persist
        assert_eq!(ts.place_write(MIB), ts.persist_idx());
    }

    #[test]
    fn zero_byte_place_skips_full_caches() {
        let (_g1, fast) = tmp("zb-fast");
        let (_g2, lus) = tmp("zb-lus");
        let ts = TierSet::new(&[fast], &lus, |t| t).unwrap();
        assert_eq!(ts.place_write(0), 0, "empty cache takes new files");
        assert!(ts.get(0).try_reserve(MIB)); // fill the cache completely
        assert_eq!(
            ts.place_write(0),
            ts.persist_idx(),
            "full cache must not accept a doomed 0-byte reservation"
        );
        ts.get(0).release(1);
        assert_eq!(ts.place_write(0), 0, "any free byte re-enables the cache");
    }

    #[test]
    fn reserve_on_cache_never_targets_persist() {
        let (_g1, fast) = tmp("roc-fast");
        let (_g2, lus) = tmp("roc-lus");
        let ts = TierSet::new(&[fast], &lus, |t| t).unwrap();
        assert_eq!(ts.reserve_on_cache(MIB / 2), Some(0));
        assert_eq!(ts.get(0).used(), MIB / 2, "reservation handed to caller");
        assert_eq!(ts.reserve_on_cache(MIB), None, "no fallthrough to persist");
        let (_g3, lus2) = tmp("roc-only");
        let baseline = TierSet::new(&[], &lus2, |t| t).unwrap();
        assert_eq!(baseline.reserve_on_cache(1), None);
    }

    #[test]
    fn filtered_placement_skips_rejected_caches() {
        let (_g1, fast) = tmp("flt-fast");
        let (_g2, slow) = tmp("flt-slow");
        let (_g3, lus) = tmp("flt-lus");
        let ts = TierSet::new(&[fast, slow], &lus, |t| t).unwrap();
        // fast (idx 0) filtered out: placement lands on slow
        assert_eq!(ts.place_write_filtered(100, |idx| idx != 0), 1);
        assert_eq!(ts.get(0).used(), 0, "no reservation on a filtered tier");
        // every cache filtered: falls through to persist
        assert_eq!(ts.place_write_filtered(100, |_| false), ts.persist_idx());
        assert_eq!(ts.place_write_filtered(0, |_| false), ts.persist_idx());
        // reserve_on_cache_filtered has no persist fallthrough
        assert_eq!(ts.reserve_on_cache_filtered(100, |idx| idx != 0), Some(1));
        assert_eq!(ts.reserve_on_cache_filtered(100, |_| false), None);
    }

    #[test]
    fn baseline_has_only_persist() {
        let (_g, lus) = tmp("only");
        let ts = TierSet::new(&[], &lus, |t| t).unwrap();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts.place_write(123), ts.persist_idx());
        assert!(ts.caches().is_empty());
    }

    #[test]
    fn throttled_tier_blocks_for_bandwidth() {
        let (_g, def) = tmp("thr");
        let tier = Tier::new(&def).unwrap().with_bandwidth_limit(10.0 * MIB as f64);
        let t0 = std::time::Instant::now();
        // 1 MiB at 10 MiB/s with a 50 ms burst (0.5 MiB) -> ~50 ms wait
        tier.wait_data(MIB);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.04, "dt={dt}");
        assert!(tier.is_throttled());
    }

    #[test]
    fn qos_counters_split_by_class() {
        let (_g, def) = tmp("qos");
        let tier = Tier::new(&def).unwrap().with_bandwidth_limit(1e9);
        tier.set_qos(true);
        tier.wait_data(100); // foreground entry point
        tier.wait_data_class(200, IoClass::Background);
        let snap = tier.qos_snapshot().unwrap();
        assert_eq!(snap.fg_bytes, 100);
        assert_eq!(snap.bg_bytes, 200);
        let (_g2, def2) = tmp("qos-off");
        assert!(Tier::new(&def2).unwrap().qos_snapshot().is_none());
    }

    #[test]
    fn meta_latency_applies_per_op() {
        let (_g, def) = tmp("meta");
        let tier = Tier::new(&def)
            .unwrap()
            .with_meta_latency(Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            tier.wait_meta();
        }
        assert!(t0.elapsed().as_millis() >= 18);
    }

    #[test]
    fn prop_concurrent_reserve_never_overflows() {
        use std::sync::Arc;
        let (_g, mut def) = tmp("conc");
        def.capacity = 1000;
        let tier = Arc::new(Tier::new(&def).unwrap());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let t = tier.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0u64;
                for _ in 0..100 {
                    if t.try_reserve(7) {
                        got += 7;
                    }
                }
                got
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, tier.used());
        assert!(tier.used() <= 1000);
    }
}
