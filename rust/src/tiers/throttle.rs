//! Token-bucket bandwidth throttle (blocking).
//!
//! Emulates a bandwidth-constrained device in real mode: callers acquire
//! tokens (bytes) and sleep until the bucket refills. The bucket allows a
//! small burst (one second of budget) so short writes aren't serialised
//! artificially — matching how a real device's queue absorbs bursts.

use std::sync::Mutex;
use std::time::{Duration, Instant};

#[derive(Debug)]
struct BucketState {
    tokens: f64,
    last_refill: Instant,
}

/// A blocking token bucket: `rate` units/second, burst of one second.
#[derive(Debug)]
pub struct Throttle {
    rate: f64,
    burst: f64,
    state: Mutex<BucketState>,
}

impl Throttle {
    pub fn new(rate: f64) -> Throttle {
        Throttle::with_burst(rate, 1.0)
    }

    /// `burst_secs` seconds of budget may pass without waiting.
    pub fn with_burst(rate: f64, burst_secs: f64) -> Throttle {
        assert!(rate > 0.0 && burst_secs > 0.0);
        Throttle {
            rate,
            burst: rate * burst_secs,
            state: Mutex::new(BucketState {
                tokens: rate * burst_secs,
                last_refill: Instant::now(),
            }),
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Take `amount` tokens, sleeping as required. Large requests are
    /// split so concurrent callers interleave fairly.
    pub fn acquire(&self, mut amount: f64) {
        let chunk = self.burst.max(1.0);
        while amount > 0.0 {
            let take = amount.min(chunk);
            self.acquire_once(take);
            amount -= take;
        }
    }

    fn acquire_once(&self, amount: f64) {
        loop {
            let wait = {
                let mut st = self.state.lock().unwrap();
                let now = Instant::now();
                let elapsed = now.duration_since(st.last_refill).as_secs_f64();
                st.tokens = (st.tokens + elapsed * self.rate).min(self.burst);
                st.last_refill = now;
                if st.tokens >= amount {
                    st.tokens -= amount;
                    return;
                }
                // sleep until enough tokens accumulate
                (amount - st.tokens) / self.rate
            };
            std::thread::sleep(Duration::from_secs_f64(wait.min(0.25)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_passes_instantly() {
        let t = Throttle::new(1000.0);
        let start = Instant::now();
        t.acquire(500.0);
        assert!(start.elapsed() < Duration::from_millis(20));
    }

    #[test]
    fn sustained_rate_enforced() {
        let t = Throttle::new(10_000.0);
        let start = Instant::now();
        // 20k tokens at 10k/s with a 10k burst -> >= ~1 s total
        t.acquire(20_000.0);
        let dt = start.elapsed().as_secs_f64();
        assert!(dt >= 0.9, "dt={dt}");
        assert!(dt < 3.0, "dt={dt}");
    }

    #[test]
    fn concurrent_acquires_share_rate() {
        use std::sync::Arc;
        let t = Arc::new(Throttle::new(20_000.0));
        let start = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || t.acquire(10_000.0))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 40k total, 20k burst + 20k/s -> >= ~1 s
        let dt = start.elapsed().as_secs_f64();
        assert!(dt >= 0.9, "dt={dt}");
    }
}
