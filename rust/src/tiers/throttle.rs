//! Token-bucket bandwidth throttle (blocking).
//!
//! Emulates a bandwidth-constrained device in real mode: callers acquire
//! tokens (bytes) and sleep until the bucket refills. The bucket allows a
//! small burst (one second of budget) so short writes aren't serialised
//! artificially — matching how a real device's queue absorbs bursts.

use std::sync::Mutex;
use std::time::{Duration, Instant};

#[derive(Debug)]
struct BucketState {
    tokens: f64,
    last_refill: Instant,
}

/// A blocking token bucket: `rate` units/second, burst of one second.
#[derive(Debug)]
pub struct Throttle {
    rate: f64,
    burst: f64,
    state: Mutex<BucketState>,
}

impl Throttle {
    pub fn new(rate: f64) -> Result<Throttle, String> {
        Throttle::with_burst(rate, 1.0)
    }

    /// `burst_secs` seconds of budget may pass without waiting.
    ///
    /// Rejects non-finite or non-positive rates/bursts: a zero or negative
    /// rate would make the refill computation divide by zero (`NaN`/`inf`
    /// sleep durations), turning every acquire into an unbounded hang.
    pub fn with_burst(rate: f64, burst_secs: f64) -> Result<Throttle, String> {
        if !rate.is_finite() || rate <= 0.0 {
            return Err(format!("throttle rate must be finite and > 0, got {rate}"));
        }
        if !burst_secs.is_finite() || burst_secs <= 0.0 {
            return Err(format!(
                "throttle burst must be finite and > 0 seconds, got {burst_secs}"
            ));
        }
        Ok(Throttle {
            rate,
            burst: rate * burst_secs,
            state: Mutex::new(BucketState {
                tokens: rate * burst_secs,
                last_refill: Instant::now(),
            }),
        })
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Take `amount` tokens, sleeping as required. Large requests are
    /// split so concurrent callers interleave fairly.
    pub fn acquire(&self, amount: f64) {
        self.acquire_tracked(amount);
    }

    /// Like [`acquire`](Throttle::acquire), but reports whether the caller
    /// had to sleep for tokens — the signal the two-class QoS layer uses to
    /// charge background debt for foreground waits.
    pub fn acquire_tracked(&self, mut amount: f64) -> bool {
        let chunk = self.burst.max(1.0);
        let mut waited = false;
        while amount > 0.0 {
            let take = amount.min(chunk);
            waited |= self.acquire_once(take);
            amount -= take;
        }
        waited
    }

    fn acquire_once(&self, amount: f64) -> bool {
        let mut waited = false;
        loop {
            let wait = {
                let mut st = self.state.lock().unwrap();
                let now = Instant::now();
                let elapsed = now.duration_since(st.last_refill).as_secs_f64();
                st.tokens = (st.tokens + elapsed * self.rate).min(self.burst);
                st.last_refill = now;
                if st.tokens >= amount {
                    st.tokens -= amount;
                    return waited;
                }
                // sleep until enough tokens accumulate
                (amount - st.tokens) / self.rate
            };
            waited = true;
            std::thread::sleep(Duration::from_secs_f64(wait.min(0.25)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invalid_rate_or_burst_is_a_config_error() {
        assert!(Throttle::with_burst(0.0, 1.0).is_err());
        assert!(Throttle::with_burst(-5.0, 1.0).is_err());
        assert!(Throttle::with_burst(f64::NAN, 1.0).is_err());
        assert!(Throttle::with_burst(f64::INFINITY, 1.0).is_err());
        assert!(Throttle::with_burst(1000.0, 0.0).is_err());
        assert!(Throttle::with_burst(1000.0, -1.0).is_err());
        assert!(Throttle::with_burst(1000.0, f64::NAN).is_err());
        assert!(Throttle::new(0.0).is_err());
        assert!(Throttle::new(1000.0).is_ok());
    }

    #[test]
    fn acquire_tracked_reports_sleeps() {
        let t = Throttle::with_burst(1_000_000.0, 1.0).unwrap();
        // fits the burst: no wait
        assert!(!t.acquire_tracked(1000.0));
        // drains past the burst: must sleep at least once
        assert!(t.acquire_tracked(2_000_000.0));
    }

    #[test]
    fn burst_passes_instantly() {
        let t = Throttle::new(1000.0).unwrap();
        let start = Instant::now();
        t.acquire(500.0);
        assert!(start.elapsed() < Duration::from_millis(20));
    }

    #[test]
    fn sustained_rate_enforced() {
        let t = Throttle::new(10_000.0).unwrap();
        let start = Instant::now();
        // 20k tokens at 10k/s with a 10k burst -> >= ~1 s total
        t.acquire(20_000.0);
        let dt = start.elapsed().as_secs_f64();
        assert!(dt >= 0.9, "dt={dt}");
        assert!(dt < 3.0, "dt={dt}");
    }

    #[test]
    fn concurrent_acquires_share_rate() {
        use std::sync::Arc;
        let t = Arc::new(Throttle::new(20_000.0).unwrap());
        let start = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || t.acquire(10_000.0))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 40k total, 20k burst + 20k/s -> >= ~1 s
        let dt = start.elapsed().as_secs_f64();
        assert!(dt >= 0.9, "dt={dt}");
    }
}
