//! The parallel transfer engine: every tier-to-tier byte move in Sea
//! (flush, prefetch, spill) goes through here.
//!
//! The paper's §2.1 background threads (flush/evict/prefetch) all reduce
//! to "copy a file between tiers while the application keeps running".
//! The seed implementation did those copies serially and wrote straight
//! to the destination's final path, which left two windows open (see
//! ROADMAP): a rename racing an in-flight flush could strand the persist
//! copy at the stale path, and a truncate-create placed directly on the
//! persist tier could share a physical inode with an in-flight flush of
//! the old incarnation and interleave bytes. This module closes both and
//! adds the pipelining that arXiv:2108.10496 shows is where the big wins
//! on degraded Lustre come from:
//!
//! * **Atomic copies** — every transfer writes to a temp name in the
//!   destination directory (`<name>.sea_tmp.<seq>`) and `fs::rename`s it
//!   into place. A reader (or a truncate-create) can never observe a
//!   half-written destination, and interrupted transfers leave only temp
//!   files, which `SeaIo::register_existing` deletes at the next mount
//!   ([`is_temp_name`]).
//! * **Per-file fencing** — a [`FenceMap`] entry marks a path as having a
//!   transfer in flight. Metadata ops that would invalidate the copy
//!   (rename, unlink, truncate-create) call [`FenceMap::block`], which
//!   cancels the in-flight transfer and waits for it to drain before
//!   claiming the path; the transfer observes the cancel between
//!   64 KiB throttle slices, deletes its temp file and reports
//!   [`Outcome::Cancelled`]. The `commit` closure (namespace bookkeeping)
//!   runs *under* the fence, so "replica recorded" and "bytes in place"
//!   are indivisible from the racing op's point of view: it sees either
//!   the whole transfer or none of it.
//! * **A bounded worker pool** — [`TransferEngine::run_batch`] fans a
//!   batch of copies over `transfer_workers` scoped threads, so one slow
//!   persist-tier file no longer delays the rest of the flusher's queue.
//! * **One buffer size** — all transfers use `SeaConfig::copy_buf_bytes`;
//!   no call site carries its own copy loop any more.
//!
//! # Thread model and lock order
//!
//! Fences extend the crate lock order documented in [`crate::intercept`]:
//! fd-shard lock → per-fd mutex → **fence** → namespace shard lock. A
//! fence holder never waits on fd or namespace locks while copying (the
//! commit closure takes namespace shard locks briefly, which is the
//! allowed fence → namespace direction), and blockers that need two
//! fences (rename) acquire them in ascending path order, so there is no
//! cycle. Transfer workers hold exactly one fence at a time and never
//! block on another, so every [`FenceMap::block`] call terminates after
//! at most one in-flight copy drains (bounded by one 64 KiB throttle
//! slice per cancel check).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::intercept::SeaCore;
use crate::namespace::CleanPath;
use crate::sched::IoClass;
use crate::tiers::TierIdx;

/// Marker embedded in every in-flight destination temp name. Paths whose
/// final component contains this marker are never registered as logical
/// files and are deleted at mount (crash leftovers).
pub const TEMP_MARKER: &str = ".sea_tmp.";

/// True if `file_name` is (or contains) a transfer temp name.
pub fn is_temp_name(file_name: &str) -> bool {
    file_name.contains(TEMP_MARKER)
}

/// Cancel-check granularity: throttle waits and writes are sliced this
/// finely so a blocked rename/unlink waits at most one slice's worth of
/// throttled bandwidth for the cancel to be honoured.
const CANCEL_SLICE: usize = 64 * 1024;

/// Marker in the error of an injected torn copy. A torn copy simulates a
/// mid-transfer power cut, so — unlike every other copy error — its
/// truncated temp file is deliberately **left behind** for mount-time
/// hygiene to find (see `crate::faults` and `SeaIo::register_existing`).
const TORN_MSG: &str = "injected torn copy";

/// Number of fence shards (power of two, FNV-hashed like the namespace).
const FENCE_SHARDS: usize = 16;

fn fence_shard_of(path: &str) -> usize {
    (crate::namespace::fnv1a(path) as usize) & (FENCE_SHARDS - 1)
}

struct FenceShard {
    /// path → cancel flag of the current holder (transfer or blocker).
    held: Mutex<HashMap<String, Arc<AtomicBool>>>,
    cv: Condvar,
}

/// Per-path in-flight transfer registry. At most one holder per path: a
/// running transfer ([`FenceMap::begin`]) or a metadata op that must not
/// race one ([`FenceMap::block`]).
pub struct FenceMap {
    shards: Vec<FenceShard>,
}

impl Default for FenceMap {
    fn default() -> Self {
        FenceMap {
            shards: (0..FENCE_SHARDS)
                .map(|_| FenceShard {
                    held: Mutex::new(HashMap::new()),
                    cv: Condvar::new(),
                })
                .collect(),
        }
    }
}

impl FenceMap {
    pub fn new() -> FenceMap {
        FenceMap::default()
    }

    fn shard(&self, path: &str) -> &FenceShard {
        &self.shards[fence_shard_of(path)]
    }

    /// Claim the fence for a transfer without waiting. Returns `None`
    /// when the path is already held (a transfer or a metadata op is in
    /// flight) — background callers skip and retry later.
    pub fn begin(&self, path: &str) -> Option<FenceGuard<'_>> {
        let shard = self.shard(path);
        let mut held = shard.held.lock().unwrap();
        if held.contains_key(path) {
            return None;
        }
        let cancel = Arc::new(AtomicBool::new(false));
        held.insert(path.to_string(), cancel.clone());
        Some(FenceGuard {
            shard,
            path: path.to_string(),
            cancel,
        })
    }

    /// Claim the fence, cancelling and waiting out any current holder.
    /// Used by ops whose progress must not be held hostage by a
    /// background copy: rename, unlink, truncate-create, spill.
    pub fn block(&self, path: &str) -> FenceGuard<'_> {
        let shard = self.shard(path);
        let mut held = shard.held.lock().unwrap();
        loop {
            match held.get(path) {
                None => {
                    let cancel = Arc::new(AtomicBool::new(false));
                    held.insert(path.to_string(), cancel.clone());
                    return FenceGuard {
                        shard,
                        path: path.to_string(),
                        cancel,
                    };
                }
                Some(holder) => {
                    holder.store(true, Ordering::Release);
                    held = shard.cv.wait(held).unwrap();
                }
            }
        }
    }

    /// True if some holder (transfer or blocker) currently owns `path`.
    pub fn is_held(&self, path: &str) -> bool {
        self.shard(path).held.lock().unwrap().contains_key(path)
    }
}

/// Exclusive hold on one path's fence. Dropping releases the path and
/// wakes blocked claimants.
pub struct FenceGuard<'a> {
    shard: &'a FenceShard,
    path: String,
    cancel: Arc<AtomicBool>,
}

impl FenceGuard<'_> {
    /// True once a [`FenceMap::block`] caller has asked this holder to
    /// abandon its work.
    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Acquire)
    }
}

impl Drop for FenceGuard<'_> {
    fn drop(&mut self) {
        let mut held = self.shard.held.lock().unwrap();
        held.remove(&self.path);
        drop(held);
        self.shard.cv.notify_all();
    }
}

/// How a single engine copy ended.
#[derive(Debug)]
pub enum Outcome<V> {
    /// Bytes are atomically in place and `commit` ran under the fence.
    Done { bytes: u64, commit: V },
    /// A racing metadata op cancelled the copy; the temp file was
    /// removed and nothing was recorded.
    Cancelled,
    /// The path's fence was already held (only from [`TransferEngine::copy`];
    /// the blocking variant never reports this).
    Busy,
}

impl<V> Outcome<V> {
    pub fn is_done(&self) -> bool {
        matches!(self, Outcome::Done { .. })
    }
}

/// Lock-free engine counters (diagnostics + benches).
#[derive(Debug, Default)]
pub struct TransferStats {
    completed: AtomicU64,
    cancelled: AtomicU64,
    errors: AtomicU64,
    bytes_moved: AtomicU64,
}

impl TransferStats {
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn bytes_moved(&self) -> u64 {
        self.bytes_moved.load(Ordering::Relaxed)
    }

    /// Point-in-time copy for reports (feeds the `sea_transfers_total`
    /// family in `SeaCore::metrics_snapshot`).
    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            completed: self.completed(),
            cancelled: self.cancelled(),
            errors: self.errors(),
            bytes_moved: self.bytes_moved(),
        }
    }
}

/// Plain-data snapshot of [`TransferStats`] at one instant.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub completed: u64,
    pub cancelled: u64,
    pub errors: u64,
    pub bytes_moved: u64,
}

/// One copy in a [`TransferEngine::run_batch`] submission. `token` is an
/// opaque caller-side index (e.g. into its entry list) carried through to
/// the commit closure and the result row.
#[derive(Debug, Clone)]
pub struct BatchJob {
    pub logical: CleanPath,
    pub from: TierIdx,
    pub to: TierIdx,
    pub token: usize,
}

/// One result row of [`TransferEngine::run_batch`]: the job, back in
/// submission order, with its copy outcome.
pub type BatchResult<V> = (BatchJob, std::io::Result<Outcome<V>>);

/// The engine proper: fence registry + worker-pool sizing + the single
/// configured copy buffer. Lives in [`SeaCore`]; worker threads are
/// scoped per batch, so the engine itself owns no threads and the
/// `SeaCore` Arc graph stays acyclic.
pub struct TransferEngine {
    workers: usize,
    copy_buf: usize,
    seq: AtomicU64,
    pub fences: FenceMap,
    pub stats: TransferStats,
}

impl TransferEngine {
    pub fn new(workers: usize, copy_buf: usize) -> TransferEngine {
        TransferEngine {
            workers: workers.max(1),
            copy_buf: copy_buf.max(4096),
            seq: AtomicU64::new(0),
            fences: FenceMap::new(),
            stats: TransferStats::default(),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Fenced atomic copy of `logical` from tier `from` to tier `to`.
    /// `commit` runs under the fence once the destination is atomically
    /// in place — namespace bookkeeping goes there, so racing metadata
    /// ops (which block on the same fence) see all of the transfer or
    /// none of it. Returns [`Outcome::Busy`] without copying when the
    /// path's fence is already held. `class` is the bandwidth class the
    /// copy's throttle waits are charged to: background callers
    /// (prefetch staging, bulk flush batches on an idle mount) yield to
    /// foreground pressure on QoS-shaped tiers.
    pub fn copy<V>(
        &self,
        core: &SeaCore,
        logical: &str,
        from: TierIdx,
        to: TierIdx,
        class: IoClass,
        commit: impl FnOnce(u64) -> V,
    ) -> std::io::Result<Outcome<V>> {
        match self.fences.begin(logical) {
            Some(guard) => self.copy_under(core, &guard, logical, from, to, class, commit),
            None => Ok(Outcome::Busy),
        }
    }

    /// Blocking variant: cancels and waits out any in-flight holder
    /// first (the spill path's "my write must proceed"). Never `Busy`.
    /// Always foreground: every caller is on an application-blocking
    /// path.
    pub fn copy_now<V>(
        &self,
        core: &SeaCore,
        logical: &str,
        from: TierIdx,
        to: TierIdx,
        commit: impl FnOnce(u64) -> V,
    ) -> std::io::Result<Outcome<V>> {
        let guard = self.fences.block(logical);
        self.copy_under(core, &guard, logical, from, to, IoClass::Foreground, commit)
    }

    fn copy_under<V>(
        &self,
        core: &SeaCore,
        guard: &FenceGuard<'_>,
        logical: &str,
        from: TierIdx,
        to: TierIdx,
        class: IoClass,
        commit: impl FnOnce(u64) -> V,
    ) -> std::io::Result<Outcome<V>> {
        let t0 = core.obs.start();
        let res = self.copy_under_inner(core, guard, logical, from, to, class, commit);
        let (bytes, outcome) = match &res {
            Ok(Outcome::Done { bytes, .. }) => (*bytes, crate::obs::EventOutcome::Ok),
            Ok(Outcome::Cancelled) => (0, crate::obs::EventOutcome::Cancelled),
            Ok(Outcome::Busy) => (0, crate::obs::EventOutcome::Busy),
            Err(_) => (0, crate::obs::EventOutcome::Err),
        };
        core.obs.record_tagged(
            crate::obs::EventKind::TransferCopy,
            Some(to),
            crate::journal::fnv1a_bytes(logical.as_bytes()),
            bytes,
            t0,
            outcome,
            core.tenants.resolve(logical),
        );
        res
    }

    fn copy_under_inner<V>(
        &self,
        core: &SeaCore,
        guard: &FenceGuard<'_>,
        logical: &str,
        from: TierIdx,
        to: TierIdx,
        class: IoClass,
        commit: impl FnOnce(u64) -> V,
    ) -> std::io::Result<Outcome<V>> {
        let dst_path = core.tiers.get(to).physical(logical);
        let tmp_path = {
            let id = self.seq.fetch_add(1, Ordering::Relaxed);
            let name = dst_path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default();
            dst_path.with_file_name(format!("{name}{TEMP_MARKER}{id}"))
        };
        if let Some(parent) = dst_path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        core.tiers.get(from).wait_meta();
        core.tiers.get(to).wait_meta();
        let total = match self.copy_bytes(core, guard, logical, from, to, class, &tmp_path) {
            Ok(Some(total)) => total,
            Ok(None) => {
                let _ = std::fs::remove_file(&tmp_path);
                self.stats.cancelled.fetch_add(1, Ordering::Relaxed);
                return Ok(Outcome::Cancelled);
            }
            Err(e) => {
                // A torn copy is the simulated power cut: its truncated
                // temp stays behind on purpose (mount hygiene's problem).
                if !e.to_string().contains(TORN_MSG) {
                    let _ = std::fs::remove_file(&tmp_path);
                }
                self.stats.errors.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        // Temp fully written + synced, rename not yet done: a crash here
        // must lose nothing (the journal still holds the file dirty).
        core.faults.crash_point("copy.before_rename");
        if let Err(e) = std::fs::rename(&tmp_path, &dst_path) {
            let _ = std::fs::remove_file(&tmp_path);
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        // Bytes in place, commit (namespace clean-marking, journal Clean
        // record) not yet run: the worst-case crash window — recovery
        // must re-discover the file dirty and re-flush idempotently.
        core.faults.crash_point("copy.after_rename");
        let v = commit(total);
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_moved.fetch_add(total, Ordering::Relaxed);
        Ok(Outcome::Done { bytes: total, commit: v })
    }

    /// The copy loop: `Ok(None)` means cancelled. Honest waiting on both
    /// tiers' throttles, sliced so cancellation is honoured promptly even
    /// on a heavily throttled tier.
    fn copy_bytes(
        &self,
        core: &SeaCore,
        guard: &FenceGuard<'_>,
        logical: &str,
        from: TierIdx,
        to: TierIdx,
        class: IoClass,
        tmp_path: &std::path::Path,
    ) -> std::io::Result<Option<u64>> {
        core.tiers.get(from).check_up()?;
        core.tiers.get(to).check_up()?;
        // Tier-level flakiness/hang injection (`tier.<name>=flaky:<rate>`,
        // `tier.<name>=hang:<ms>`): one roll per copy per side, so the
        // health engine sees failures attributed to the tier by name.
        core.faults.tier_io(&core.tiers.get(from).name)?;
        core.faults.tier_io(&core.tiers.get(to).name)?;
        let torn_at = core.faults.torn_limit("copy.write");
        let src_path = core.tiers.get(from).physical(logical);
        let mut src = std::fs::File::open(&src_path)?;
        let mut dst = std::fs::File::create(tmp_path)?;
        let mut buf = vec![0u8; self.copy_buf];
        let mut total = 0u64;
        let mut first_slice = true;
        // Background traffic is billed to the owning tenant's bandwidth
        // lane (single-tenant: tag 0, identical to the untagged path).
        let tenant = core.tenants.resolve(logical);
        let mut yields = 0u32;
        loop {
            core.faults.check_io("copy.read")?;
            let n = src.read(&mut buf)?;
            if n == 0 {
                break;
            }
            for slice in buf[..n].chunks(CANCEL_SLICE) {
                if guard.cancelled() {
                    core.tenants.note_yields(tenant, yields);
                    return Ok(None);
                }
                yields += core
                    .tiers
                    .get(from)
                    .wait_data_tagged(slice.len() as u64, class, tenant);
                yields += core
                    .tiers
                    .get(to)
                    .wait_data_tagged(slice.len() as u64, class, tenant);
                core.faults.check_io("copy.write")?;
                if let Some(limit) = torn_at {
                    let room = limit.saturating_sub(total);
                    if (slice.len() as u64) > room {
                        dst.write_all(&slice[..room as usize])?;
                        let _ = dst.sync_all();
                        return Err(std::io::Error::other(format!(
                            "{TORN_MSG} after {limit} bytes"
                        )));
                    }
                }
                dst.write_all(slice)?;
                total += slice.len() as u64;
                if first_slice {
                    first_slice = false;
                    // Crash with a half-written temp on disk.
                    core.faults.crash_point("copy.mid_write");
                }
            }
        }
        dst.sync_all()?;
        core.tenants.note_yields(tenant, yields);
        if guard.cancelled() {
            return Ok(None);
        }
        Ok(Some(total))
    }

    /// Pipeline a batch of copies over the bounded worker pool. Each
    /// job's `commit` runs under that job's fence on the worker thread;
    /// results come back in submission order for serial post-processing.
    /// Jobs whose fence is held report [`Outcome::Busy`] (no waiting).
    /// `class` applies to every job's throttle waits — the flusher's
    /// persist drain is foreground (dirty data durability blocks the
    /// application's progress budget), prefetch staging is background.
    pub fn run_batch<V, C>(
        &self,
        core: &SeaCore,
        jobs: Vec<BatchJob>,
        class: IoClass,
        commit: C,
    ) -> Vec<BatchResult<V>>
    where
        V: Send,
        C: Fn(&BatchJob, u64) -> V + Sync,
    {
        type Slot<V> = Mutex<Option<std::io::Result<Outcome<V>>>>;
        if jobs.is_empty() {
            return Vec::new();
        }
        let n_workers = self.workers.min(jobs.len());
        if n_workers == 1 {
            return jobs
                .into_iter()
                .map(|job| {
                    let r =
                        self.copy(core, job.logical.as_str(), job.from, job.to, class, |b| {
                            commit(&job, b)
                        });
                    (job, r)
                })
                .collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Slot<V>> = jobs.iter().map(|_| Mutex::new(None)).collect();
        {
            let jobs_ref = &jobs;
            let next_ref = &next;
            let slots_ref = &slots;
            let commit_ref = &commit;
            std::thread::scope(|s| {
                for _ in 0..n_workers {
                    s.spawn(move || loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs_ref.len() {
                            break;
                        }
                        let job = &jobs_ref[i];
                        let r = self
                            .copy(core, job.logical.as_str(), job.from, job.to, class, |b| {
                                commit_ref(job, b)
                            });
                        *slots_ref[i].lock().unwrap() = Some(r);
                    });
                }
            });
        }
        jobs.into_iter()
            .zip(slots)
            .map(|(job, slot)| {
                let r = slot.into_inner().unwrap().expect("batch worker filled slot");
                (job, r)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SeaConfig;
    use crate::intercept::SeaIo;
    use crate::pathrules::SeaLists;
    use crate::testing::tempdir::{tempdir, TempDirGuard};
    use crate::util::MIB;
    use std::time::Duration;

    fn setup() -> (TempDirGuard, SeaIo) {
        let dir = tempdir("transfer");
        let cfg = SeaConfig::builder(dir.subdir("mount"))
            .cache("tmpfs", dir.subdir("tmpfs"), 16 * MIB)
            .persist("lustre", dir.subdir("lustre"), 100 * MIB)
            .build();
        let sea = SeaIo::mount_with(cfg, SeaLists::default(), |t| t).unwrap();
        (dir, sea)
    }

    fn write_file(sea: &SeaIo, path: &str, data: &[u8]) {
        let fd = sea.create(path).unwrap();
        sea.write(fd, data).unwrap();
        sea.close(fd).unwrap();
    }

    #[test]
    fn temp_names_detected() {
        assert!(is_temp_name("bold.nii.sea_tmp.17"));
        assert!(!is_temp_name("bold.nii"));
        assert!(!is_temp_name("sea_tmp"));
    }

    #[test]
    fn fence_begin_is_exclusive_until_drop() {
        let fences = FenceMap::new();
        let g = fences.begin("/a").expect("first claim");
        assert!(fences.begin("/a").is_none(), "double claim");
        assert!(fences.begin("/b").is_some(), "other paths unaffected");
        assert!(fences.is_held("/a"));
        drop(g);
        assert!(!fences.is_held("/a"));
        assert!(fences.begin("/a").is_some());
    }

    #[test]
    fn block_cancels_holder_and_waits() {
        let fences = FenceMap::new();
        let g = fences.begin("/x").unwrap();
        std::thread::scope(|s| {
            let fences = &fences;
            let h = s.spawn(move || {
                let _b = fences.block("/x");
                // claimed only after the transfer guard drops
            });
            // the blocker must have set our cancel flag
            while !g.cancelled() {
                std::thread::sleep(Duration::from_millis(1));
            }
            assert!(!h.is_finished(), "blocker claimed while we still hold");
            drop(g);
            h.join().unwrap();
        });
        assert!(!fences.is_held("/x"));
    }

    #[test]
    fn engine_copy_lands_atomically_and_commits() {
        let (_g, sea) = setup();
        write_file(&sea, "/d/a.out", b"payload");
        let core = sea.core();
        let persist = core.tiers.persist_idx();
        let mut committed = 0u64;
        let out = core
            .transfers
            .copy(core, "/d/a.out", 0, persist, IoClass::Foreground, |b| {
                committed = b;
            })
            .unwrap();
        assert!(out.is_done());
        assert_eq!(committed, 7);
        let dst = core.tiers.persist().physical("/d/a.out");
        assert_eq!(std::fs::read(&dst).unwrap(), b"payload");
        // no temp litter next to the destination
        for entry in std::fs::read_dir(dst.parent().unwrap()).unwrap().flatten() {
            assert!(!is_temp_name(&entry.file_name().to_string_lossy()));
        }
        assert_eq!(core.transfers.stats.completed(), 1);
        assert_eq!(core.transfers.stats.bytes_moved(), 7);
    }

    #[test]
    fn copy_reports_busy_when_fence_held() {
        let (_g, sea) = setup();
        write_file(&sea, "/d/b.out", b"x");
        let core = sea.core();
        let persist = core.tiers.persist_idx();
        let _held = core.transfers.fences.begin("/d/b.out").unwrap();
        let out = core
            .transfers
            .copy(core, "/d/b.out", 0, persist, IoClass::Background, |_| ())
            .unwrap();
        assert!(matches!(out, Outcome::Busy));
        assert!(!core.tiers.persist().physical("/d/b.out").exists());
    }

    #[test]
    fn cancelled_copy_removes_temp_and_skips_commit() {
        let (_g, sea) = setup();
        write_file(&sea, "/d/c.out", &[3u8; 256 * 1024]);
        let core = sea.core();
        let persist = core.tiers.persist_idx();
        // Pre-cancel via a blocker racing the copy: claim, then copy with
        // the *blocking* variant from another thread and cancel it.
        std::thread::scope(|s| {
            let started = std::sync::atomic::AtomicBool::new(false);
            let started = &started;
            let h = s.spawn(move || {
                core.transfers.copy_now(core, "/d/c.out", 0, persist, |_| {
                    started.store(true, Ordering::Release);
                })
            });
            // A concurrent blocker: whichever side loses the race, the
            // engine must never leave a temp file or a torn destination.
            let _b = core.transfers.fences.block("/d/c.out");
            let out = h.join().unwrap().unwrap();
            match out {
                Outcome::Done { bytes, .. } => {
                    assert_eq!(bytes, 256 * 1024);
                    assert!(started.load(Ordering::Acquire));
                }
                Outcome::Cancelled => {
                    assert!(!started.load(Ordering::Acquire), "commit ran on cancel");
                    assert!(!core.tiers.persist().physical("/d/c.out").exists());
                }
                Outcome::Busy => panic!("copy_now never reports Busy"),
            }
        });
        let root = core.tiers.persist().root().to_path_buf();
        let mut stack = vec![root];
        while let Some(d) = stack.pop() {
            if let Ok(entries) = std::fs::read_dir(&d) {
                for e in entries.flatten() {
                    let p = e.path();
                    if p.is_dir() {
                        stack.push(p);
                    } else {
                        assert!(
                            !is_temp_name(&e.file_name().to_string_lossy()),
                            "temp litter: {p:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn injected_eio_fails_copy_and_counts_error() {
        let dir = tempdir("transfer-eio");
        let cfg = SeaConfig::builder(dir.subdir("mount"))
            .cache("tmpfs", dir.subdir("tmpfs"), 16 * MIB)
            .persist("lustre", dir.subdir("lustre"), 100 * MIB)
            .faults("copy.write=eio")
            .build();
        let sea = SeaIo::mount_with(cfg, SeaLists::default(), |t| t).unwrap();
        write_file(&sea, "/d/e.out", b"payload");
        let core = sea.core();
        let persist = core.tiers.persist_idx();
        let err = core
            .transfers
            .copy(core, "/d/e.out", 0, persist, IoClass::Foreground, |_| ())
            .unwrap_err();
        assert!(err.to_string().contains("injected EIO"), "{err}");
        assert_eq!(core.transfers.stats.errors(), 1);
        assert!(!core.tiers.persist().physical("/d/e.out").exists());
        // The fault is one-shot: the retry succeeds.
        let out = core
            .transfers
            .copy(core, "/d/e.out", 0, persist, IoClass::Foreground, |_| ())
            .unwrap();
        assert!(out.is_done());
    }

    #[test]
    fn torn_copy_leaves_truncated_temp_behind() {
        let dir = tempdir("transfer-torn");
        let cfg = SeaConfig::builder(dir.subdir("mount"))
            .cache("tmpfs", dir.subdir("tmpfs"), 16 * MIB)
            .persist("lustre", dir.subdir("lustre"), 100 * MIB)
            .faults("copy.write=torn:3")
            .build();
        let sea = SeaIo::mount_with(cfg, SeaLists::default(), |t| t).unwrap();
        write_file(&sea, "/d/t.out", b"payload");
        let core = sea.core();
        let persist = core.tiers.persist_idx();
        let err = core
            .transfers
            .copy(core, "/d/t.out", 0, persist, IoClass::Foreground, |_| ())
            .unwrap_err();
        assert!(err.to_string().contains("torn"), "{err}");
        assert!(!core.tiers.persist().physical("/d/t.out").exists());
        let dir_of = core.tiers.persist().physical("/d/t.out");
        let temps: Vec<_> = std::fs::read_dir(dir_of.parent().unwrap())
            .unwrap()
            .flatten()
            .filter(|e| is_temp_name(&e.file_name().to_string_lossy()))
            .collect();
        assert_eq!(temps.len(), 1, "torn copy must leave its temp");
        assert_eq!(temps[0].metadata().unwrap().len(), 3, "truncated at limit");
    }

    #[test]
    fn down_tier_refuses_transfers() {
        let dir = tempdir("transfer-down");
        let cfg = SeaConfig::builder(dir.subdir("mount"))
            .cache("tmpfs", dir.subdir("tmpfs"), 16 * MIB)
            .persist("lustre", dir.subdir("lustre"), 100 * MIB)
            .faults("tier.lustre=down")
            .build();
        let sea = SeaIo::mount_with(cfg, SeaLists::default(), |t| t).unwrap();
        write_file(&sea, "/d/dn.out", b"payload");
        let core = sea.core();
        let persist = core.tiers.persist_idx();
        let err = core
            .transfers
            .copy(core, "/d/dn.out", 0, persist, IoClass::Foreground, |_| ())
            .unwrap_err();
        assert!(err.to_string().contains("down"), "{err}");
        assert!(!core.tiers.persist().physical("/d/dn.out").exists());
    }

    #[test]
    fn run_batch_pipelines_all_jobs() {
        let (_g, sea) = setup();
        let n = 10usize;
        for i in 0..n {
            write_file(&sea, &format!("/b/f{i}.out"), &[i as u8; 512]);
        }
        let core = sea.core();
        let persist = core.tiers.persist_idx();
        let jobs: Vec<BatchJob> = (0..n)
            .map(|i| BatchJob {
                logical: CleanPath::new(&format!("/b/f{i}.out")),
                from: 0,
                to: persist,
                token: i,
            })
            .collect();
        let results = core.transfers.run_batch(core, jobs, IoClass::Background, |job, bytes| {
            assert_eq!(bytes, 512);
            job.token
        });
        assert_eq!(results.len(), n);
        for (job, res) in results {
            match res.unwrap() {
                Outcome::Done { bytes, commit } => {
                    assert_eq!(bytes, 512);
                    assert_eq!(commit, job.token);
                }
                other => panic!("{}: {other:?}", job.logical),
            }
            assert!(core.tiers.persist().physical(job.logical.as_str()).exists());
        }
        assert_eq!(core.transfers.stats.completed(), n as u64);
    }
}
