//! Byte-size constants, parsing and humanised formatting.

pub const KB: u64 = 1_000;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;
pub const TB: u64 = 1_000_000_000_000;
pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;

/// Humanised binary formatting: `1536 -> "1.5 KiB"`.
pub fn format_bytes(n: u64) -> String {
    const UNITS: [(&str, u64); 4] =
        [("TiB", TIB), ("GiB", GIB), ("MiB", MIB), ("KiB", KIB)];
    for (unit, size) in UNITS {
        if n >= size {
            return format!("{:.1} {unit}", n as f64 / size as f64);
        }
    }
    format!("{n} B")
}

/// Parse sizes like `"64MiB"`, `"1.5 GB"`, `"283G"`, `"1024"` (bytes).
/// Single-letter suffixes are binary (`K`=KiB) matching sea.ini convention.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let s = s.trim();
    let split = s
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(s.len());
    let (num, suffix) = s.split_at(split);
    let value: f64 = num
        .trim()
        .parse()
        .map_err(|e| format!("bad byte size {s:?}: {e}"))?;
    let mult = match suffix.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kib" => KIB,
        "m" | "mib" => MIB,
        "g" | "gib" => GIB,
        "t" | "tib" => TIB,
        "kb" => KB,
        "mb" => MB,
        "gb" => GB,
        "tb" => TB,
        other => return Err(format!("unknown byte suffix {other:?} in {s:?}")),
    };
    Ok((value * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_each_magnitude() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(1536), "1.5 KiB");
        assert_eq!(format_bytes(3 * MIB), "3.0 MiB");
        assert_eq!(format_bytes(2 * GIB), "2.0 GiB");
        assert_eq!(format_bytes(5 * TIB), "5.0 TiB");
    }

    #[test]
    fn parses_round_trip() {
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("64MiB").unwrap(), 64 * MIB);
        assert_eq!(parse_bytes("1.5 GiB").unwrap(), 3 * GIB / 2);
        assert_eq!(parse_bytes("283 GB").unwrap(), 283 * GB);
        assert_eq!(parse_bytes("125G").unwrap(), 125 * GIB);
        assert_eq!(parse_bytes("2k").unwrap(), 2048);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("12 parsecs").is_err());
        assert!(parse_bytes("").is_err());
    }
}
