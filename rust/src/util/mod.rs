//! Small shared utilities: deterministic PRNG, byte-size formatting, timing.

pub mod bytes;
pub mod rng;

pub use bytes::{format_bytes, parse_bytes, GB, GIB, KB, KIB, MB, MIB, TB, TIB};
pub use rng::Rng;

/// Monotonic stopwatch used by the real-mode benchmarks.
#[derive(Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_secs() >= 0.004);
    }
}
