//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! The vendored crate set has no `rand`, so the simulator, the dataset
//! generator and the in-tree property-testing framework all draw from this
//! implementation. Algorithms follow Blackman & Vigna's reference C code.

/// xoshiro256++ PRNG, seeded via SplitMix64 so any u64 seed is acceptable.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-actor determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Panics if `lo > hi`.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "u64_in: {lo} > {hi}");
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        // Lemire-style rejection-free-enough bounded draw.
        lo + self.next_u64() % (span + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo.wrapping_add(self.u64_in(0, (hi - lo) as u64) as i64)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Log-normal parameterised by the *target* median and sigma of ln.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.usize_in(0, items.len() - 1)]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_in(0, i);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn u64_in_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.u64_in(10, 20);
            assert!((10..=20).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
