//! Crash-consistency scenario harness: kill a Sea mount at every named
//! crash point mid-flush, remount, and assert the journal invariant —
//! every byte written before the crash is either on the persist tier
//! already or re-discovered as dirty and flushed on the next drain.
//!
//! Two crash mechanisms:
//!
//! - **Re-exec** (`crash_child`): the parent spawns this same test binary
//!   with `SEA_CRASH_DIR` + `SEA_FAULTS=<point>=crash` in the
//!   environment; the child mounts over the shared directory, writes a
//!   deterministic file set, flushes, and aborts at the armed crash
//!   point (SIGABRT, whole process — threads, fds and all). This is the
//!   closest a test can get to `kill -9` mid-copy.
//! - **In-process forget**: `std::mem::forget(session)` skips every
//!   destructor (no drain, no journal compaction, fds leak) — a cheap
//!   stand-in for a crash when the scenario needs to keep running in the
//!   same process (tampering with the journal, double crashes).

use std::path::{Path, PathBuf};

use sea::config::SeaConfig;
use sea::flusher::SeaSession;
use sea::pathrules::{PathRules, SeaLists};
use sea::testing::tempdir::tempdir;
use sea::util::MIB;

const CRASH_DIR_ENV: &str = "SEA_CRASH_DIR";

/// Mount over `dir` with flusher/prefetcher threads off: flushing only
/// happens when a test asks for it, so crash points fire deterministically.
fn mount_at(dir: &Path, journal: bool, faults: &str) -> SeaSession {
    let cfg = SeaConfig::builder(dir.join("mount"))
        .cache("tmpfs", dir.join("tmpfs"), 64 * MIB)
        .persist("lustre", dir.join("lustre"), 100_000 * MIB)
        .flusher(false, 3_600_000)
        .prefetcher(false)
        .journal(journal)
        .faults(faults)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(".*").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    );
    SeaSession::start(cfg, lists, |t| t).unwrap()
}

/// The deterministic file set both the crash child and the verifying
/// parent derive independently (no manifest file to get torn).
fn crash_files() -> Vec<(String, Vec<u8>)> {
    vec![
        ("/sub-01/anat/T1w.nii".to_string(), pattern(3, 192 * 1024)),
        ("/sub-01/func/bold.nii".to_string(), pattern(7, 5 * 1024)),
        ("/derivatives/mask.nii".to_string(), pattern(11, 300)),
    ]
}

fn pattern(seed: u8, len: usize) -> Vec<u8> {
    (0..len).map(|i| (i as u8).wrapping_mul(seed)).collect()
}

fn write_all(sea: &sea::intercept::SeaIo, files: &[(String, Vec<u8>)]) {
    for (path, bytes) in files {
        let fd = sea.create(path).unwrap();
        sea.write(fd, bytes).unwrap();
        sea.close(fd).unwrap();
    }
}

fn persist_bytes(dir: &Path, logical: &str) -> Option<Vec<u8>> {
    std::fs::read(dir.join("lustre").join(logical.trim_start_matches('/'))).ok()
}

/// Re-exec helper: only does real work when the parent armed the
/// environment; in a normal test run it is an instant no-op pass.
#[test]
fn crash_child() {
    let Ok(dir) = std::env::var(CRASH_DIR_ENV) else {
        return;
    };
    let dir = PathBuf::from(dir);
    let sess = mount_at(&dir, true, "");
    write_all(sess.io(), &crash_files());
    // The armed crash point aborts the process somewhere in here.
    let report = sess.flush_now();
    panic!("crash point never fired (flush report: {report:?})");
}

/// The tentpole invariant, at every copy-path crash point.
#[test]
fn crash_at_every_copy_point_loses_no_bytes() {
    let exe = std::env::current_exe().unwrap();
    for point in ["copy.mid_write", "copy.before_rename", "copy.after_rename"] {
        let dir = tempdir(&format!("crash-{}", point.replace('.', "-")));
        let out = std::process::Command::new(&exe)
            .args(["crash_child", "--exact", "--nocapture"])
            .env(CRASH_DIR_ENV, dir.path())
            .env(sea::faults::ENV_FAULTS, format!("{point}=crash"))
            .output()
            .unwrap();
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            !out.status.success(),
            "{point}: child survived its crash point\n{stderr}"
        );
        assert!(
            stderr.contains("crash point"),
            "{point}: child died without hitting the crash point\n{stderr}"
        );
        // Remount over the wreckage; unmount drains whatever the journal
        // re-discovered as dirty.
        let sess = mount_at(dir.path(), true, "");
        let (_stats, report) = sess.unmount();
        for (logical, expected) in crash_files() {
            let got = persist_bytes(dir.path(), &logical);
            assert_eq!(
                got.as_deref(),
                Some(expected.as_slice()),
                "{point}: {logical} lost or corrupted after recovery \
                 (drain report: {report:?})"
            );
        }
    }
}

/// A dirty journal entry whose cache replica vanished has nothing left
/// to recover: it must be dropped, not resurrected as an empty file.
#[test]
fn vanished_replica_is_dropped_not_resurrected() {
    let dir = tempdir("crash-vanish");
    let sess = mount_at(dir.path(), true, "");
    write_all(sess.io(), &[("/gone.nii".to_string(), pattern(5, 2048))]);
    std::mem::forget(sess); // crash: journal keeps the dirty record
    std::fs::remove_file(dir.path().join("tmpfs/gone.nii")).unwrap();

    let sess = mount_at(dir.path(), true, "");
    assert!(sess.io().stat("/gone.nii").is_err(), "must not resurrect");
    let (_stats, report) = sess.unmount();
    assert_eq!(report.flushed + report.moved, 0, "{report:?}");
    assert_eq!(persist_bytes(dir.path(), "/gone.nii"), None);
}

/// A file renamed after its dirty record was journaled must recover
/// under the new name only (the rename record retargets the old one).
#[test]
fn renamed_then_crashed_path_recovers_under_new_name() {
    let dir = tempdir("crash-rename");
    let sess = mount_at(dir.path(), true, "");
    let payload = pattern(9, 40 * 1024);
    write_all(sess.io(), &[("/old.nii".to_string(), payload.clone())]);
    sess.io().rename("/old.nii", "/new.nii").unwrap();
    std::mem::forget(sess);

    let sess = mount_at(dir.path(), true, "");
    let (_stats, report) = sess.unmount();
    assert!(report.flushed + report.moved >= 1, "{report:?}");
    assert_eq!(persist_bytes(dir.path(), "/new.nii"), Some(payload));
    assert_eq!(persist_bytes(dir.path(), "/old.nii"), None);
}

/// Crash again *during* the recovery flush: the compacted journal must
/// still carry the entry, so a third mount finishes the job.
#[test]
fn double_crash_during_recovery_is_idempotent() {
    let dir = tempdir("crash-double");
    let payload = pattern(13, 64 * 1024);
    let sess = mount_at(dir.path(), true, "");
    write_all(sess.io(), &[("/twice.nii".to_string(), payload.clone())]);
    std::mem::forget(sess); // first crash

    // Second mount recovers the entry, then its flush dies on injected
    // EIO and the whole session "crashes" before any retry.
    let sess = mount_at(dir.path(), true, "copy.write=eio:1");
    let report = sess.flush_now();
    assert_eq!(report.errors, 1, "{report:?}");
    std::mem::forget(sess); // second crash

    let sess = mount_at(dir.path(), true, "");
    let (_stats, report) = sess.unmount();
    assert!(report.flushed + report.moved >= 1, "{report:?}");
    assert_eq!(persist_bytes(dir.path(), "/twice.nii"), Some(payload));
}

/// A crash-corrupted replica (same size, different bytes) is caught by
/// the journaled content hash: recovery deletes it and counts it
/// (`sea_recovery_corrupt_replica_total`) instead of flushing garbage
/// to the persist tier. Size checks alone cannot see this case.
#[test]
fn corrupted_replica_is_detected_and_never_flushed() {
    let dir = tempdir("crash-corrupt");
    let sess = mount_at(dir.path(), true, "");
    let payload = pattern(23, 16 * 1024);
    write_all(sess.io(), &[("/bitrot.nii".to_string(), payload.clone())]);
    std::mem::forget(sess); // crash: journal holds dirty record + hash

    // Flip bytes in the middle of the cache replica, keeping the size.
    let replica = dir.path().join("tmpfs/bitrot.nii");
    let mut bytes = std::fs::read(&replica).unwrap();
    for b in bytes[1024..2048].iter_mut() {
        *b ^= 0xFF;
    }
    assert_eq!(bytes.len(), payload.len());
    std::fs::write(&replica, &bytes).unwrap();

    let sess = mount_at(dir.path(), true, "");
    let core = sess.io().core().clone();
    assert_eq!(core.obs.corrupt_replicas(), 1, "corruption not detected");
    assert_eq!(
        core.metrics_snapshot()
            .value("sea_recovery_corrupt_replica_total"),
        Some(1)
    );
    // Nothing recoverable survives: no resurrection, no garbage flushed.
    assert!(sess.io().stat("/bitrot.nii").is_err());
    assert!(!replica.exists(), "corrupt replica must be deleted");
    let (_stats, report) = sess.unmount();
    assert_eq!(report.flushed + report.moved, 0, "{report:?}");
    assert_eq!(persist_bytes(dir.path(), "/bitrot.nii"), None);
}

/// Reopening a journaled-dirty file for writing invalidates its hash
/// (an in-place rewrite is indistinguishable from corruption by bytes
/// alone): a crash with the fd still open must recover the rewritten
/// bytes as unverifiable rather than wrongly deleting them as corrupt.
#[test]
fn rewrite_in_place_invalidates_hash_instead_of_vetoing_recovery() {
    use sea::intercept::OpenMode;

    let dir = tempdir("crash-rehash");
    let sess = mount_at(dir.path(), true, "");
    let payload = pattern(29, 4096);
    write_all(sess.io(), &[("/rw.nii".to_string(), payload)]);

    // Same-size in-place rewrite through a ReadWrite fd, then crash
    // before close — the close-time checkpoint never runs, so the only
    // protection is the open-time hash-invalidation record.
    let patch = pattern(31, 4096);
    let fd = sess.io().open("/rw.nii", OpenMode::ReadWrite).unwrap();
    sess.io().write(fd, &patch).unwrap();
    std::mem::forget(sess);

    let sess = mount_at(dir.path(), true, "");
    assert_eq!(
        sess.io().core().obs.corrupt_replicas(),
        0,
        "legitimate rewrite misflagged as corruption"
    );
    let (_stats, report) = sess.unmount();
    assert!(report.flushed + report.moved >= 1, "{report:?}");
    assert_eq!(persist_bytes(dir.path(), "/rw.nii"), Some(patch));
}

/// Garbage appended past the last good record (a torn tail from a crash
/// mid-append) must not poison replay of the records before it.
#[test]
fn torn_journal_tail_is_tolerated() {
    use std::io::Write;

    let dir = tempdir("crash-torn-tail");
    let sess = mount_at(dir.path(), true, "");
    let payload = pattern(17, 8 * 1024);
    write_all(sess.io(), &[("/tail.nii".to_string(), payload.clone())]);
    std::mem::forget(sess);

    // A frame header promising 100 payload bytes, then only 4 of them.
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(dir.path().join("tmpfs").join(sea::journal::JOURNAL_FILE))
        .unwrap();
    f.write_all(&100u32.to_le_bytes()).unwrap();
    f.write_all(&[0xde, 0xad, 0xbe, 0xef]).unwrap();
    drop(f);

    let sess = mount_at(dir.path(), true, "");
    let (_stats, report) = sess.unmount();
    assert!(report.flushed + report.moved >= 1, "{report:?}");
    assert_eq!(persist_bytes(dir.path(), "/tail.nii"), Some(payload));
}

/// PR-9 degraded-mode recovery: the cache tier drops partway through
/// flushing the workload and the process crashes; the next mount comes
/// up with the tier *still* down. The health engine must hold the
/// stranded files dirty — zero flush errors, zero resurrection-or-loss —
/// across that degraded mount, and a final healthy mount must land every
/// pre-crash byte on the persist tier.
#[test]
fn tier_down_across_crash_keeps_bytes_until_recovery() {
    use sea::health::TierState;

    let dir = tempdir("crash-tier-down");
    let files = crash_files();

    // Flush drops the tier mid-workload: the first file persists while
    // the tier is healthy, then the breaker flag goes down and the
    // remaining flush attempts fail over to the health engine's silent
    // re-queue (no errors — the prober owns re-admission).
    let sess = mount_at(dir.path(), true, "");
    write_all(sess.io(), &files[..1]);
    let report = sess.flush_now();
    assert_eq!(report.errors, 0, "{report:?}");
    write_all(sess.io(), &files[1..]);
    let core = sess.io().core().clone();
    core.tiers.get(0).set_down(true);
    let report = sess.flush_now();
    assert_eq!(report.errors, 0, "down tier must degrade, not error: {report:?}");
    assert!(report.backed_off >= 2, "{report:?}");
    // != Up, not == Down: the prober may hold the slot in its transient
    // Probing state for a moment while the probe gets vetoed.
    assert_ne!(core.health.state(0), TierState::Up, "breaker never tripped");
    std::mem::forget(sess); // crash with two files stranded dirty

    // Remount with the tier still down: recovery re-discovers the dirty
    // records, the drain keeps re-queueing them without surfacing an
    // error, and the compacted journal carries them forward.
    let sess = mount_at(dir.path(), true, "tier.tmpfs=down");
    let core = sess.io().core().clone();
    let (_stats, report) = sess.unmount();
    assert_eq!(report.errors, 0, "degraded drain must not error: {report:?}");
    assert_eq!(report.flushed + report.moved, 0, "{report:?}");
    assert!(report.backed_off >= 1, "{report:?}");
    assert_ne!(core.health.state(0), TierState::Up);
    assert_eq!(
        persist_bytes(dir.path(), &files[0].0).as_deref(),
        Some(files[0].1.as_slice()),
        "pre-drop flush lost"
    );
    assert_eq!(
        persist_bytes(dir.path(), &files[1].0),
        None,
        "a down tier cannot have flushed"
    );

    // Healthy mount: everything stranded finally reaches the persist tier.
    let sess = mount_at(dir.path(), true, "");
    let (_stats, report) = sess.unmount();
    assert!(report.flushed + report.moved >= 2, "{report:?}");
    assert_eq!(report.errors, 0, "{report:?}");
    for (logical, expected) in &files {
        assert_eq!(
            persist_bytes(dir.path(), logical).as_deref(),
            Some(expected.as_slice()),
            "{logical} lost across the degraded mount"
        );
    }
}

/// `[journal] enabled = false` reproduces the pre-journal lossy
/// behaviour: a crash strands dirty cache bytes forever. This pins the
/// opt-out so the journal's value stays measurable.
#[test]
fn journal_disabled_reproduces_lossy_behaviour() {
    let dir = tempdir("crash-lossy");
    let sess = mount_at(dir.path(), false, "");
    write_all(sess.io(), &[("/lost.nii".to_string(), pattern(19, 4096))]);
    std::mem::forget(sess);

    let sess = mount_at(dir.path(), false, "");
    assert!(sess.io().stat("/lost.nii").is_err(), "nothing remembers it");
    let (_stats, report) = sess.unmount();
    assert_eq!(report.flushed + report.moved, 0, "{report:?}");
    assert_eq!(persist_bytes(dir.path(), "/lost.nii"), None);
}
