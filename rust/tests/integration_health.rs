//! PR-9 acceptance tests for the tier-health subsystem: a cache tier
//! going down mid-run must degrade the mount, not fail it.
//!
//! Three pins:
//!
//! 1. With `tier.<cache>=down` tripping mid-run, the pipeline completes
//!    with zero surfaced I/O errors, every written byte lands on the
//!    persist tier, and `sea_tier_health{tier=...}` reflects the
//!    Up → Down → Up transition.
//! 2. With `[health] enabled = false`, the old fail-fast behaviour is
//!    reproduced exactly: flush errors surface in the report and the
//!    state machine never moves.
//! 3. A malformed `[faults] spec` is a mount-time configuration error
//!    that names the offending token (`SeaError::BadValue`), not an
//!    opaque I/O failure later.

use std::time::{Duration, Instant};

use sea::config::SeaConfig;
use sea::flusher::{flush_pass, SeaSession};
use sea::health::TierState;
use sea::intercept::{OpenMode, SeaError, SeaIo};
use sea::pathrules::{PathRules, SeaLists};
use sea::testing::tempdir::tempdir;
use sea::util::MIB;

fn flush_lists() -> SeaLists {
    SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    )
}

fn payload(i: usize) -> Vec<u8> {
    (0..2048).map(|b| (b as u8).wrapping_mul(i as u8 | 1)).collect()
}

#[test]
fn down_cache_tier_mid_run_completes_pipeline_without_errors() {
    let dir = tempdir("health-downrun");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(false, 3_600_000)
        .prefetcher(false)
        .health_probe_interval(50)
        .build();
    let sess = SeaSession::start(cfg, flush_lists(), |t| t).unwrap();
    let sea = sess.io();
    let core = sea.core().clone();

    // Act 1: a healthy first third of the pipeline, flushed to persist.
    for i in 0..8 {
        let fd = sea.create(&format!("/act1/f{i}.out")).unwrap();
        sea.write(fd, &payload(i)).unwrap();
        sea.close(fd).unwrap();
    }
    let report = sess.flush_now();
    assert_eq!(report.errors, 0, "{report:?}");
    assert_eq!(core.health.state(0), TierState::Up);

    // Act 2: the cache tier drops mid-run. Every application call must
    // keep succeeding — creates/writes/read-backs of old and new files.
    core.tiers.get(0).set_down(true);
    for i in 0..8 {
        let fd = sea.create(&format!("/act2/f{i}.out")).unwrap();
        sea.write(fd, &payload(i + 8)).unwrap();
        sea.close(fd).unwrap();
        let fd = sea.open(&format!("/act1/f{i}.out"), OpenMode::Read).unwrap();
        let mut buf = vec![0u8; 2048];
        let n = sea.read(fd, &mut buf).unwrap();
        assert_eq!(&buf[..n], payload(i).as_slice());
        sea.close(fd).unwrap();
    }
    // A flush against the dead tier degrades (silent re-queue), trips
    // the breaker, and surfaces no error.
    let report = sess.flush_now();
    assert_eq!(report.errors, 0, "down tier must degrade, not error: {report:?}");
    // != Up rather than == Down: the live prober may hold the slot in its
    // transient Probing state for a moment while its probe gets vetoed.
    assert_ne!(core.health.state(0), TierState::Up, "breaker never tripped");

    // The metric the alarm expression watches reflects the transition:
    // `sea_tier_health{tier=...} != 0` means degraded.
    let snap = core.metrics_snapshot();
    let health = snap
        .counters
        .iter()
        .find(|c| {
            c.name == "sea_tier_health"
                && c.labels.iter().any(|(k, v)| k == "tier" && v == "tmpfs")
        })
        .expect("sea_tier_health{tier=tmpfs} missing");
    assert_ne!(health.value, 0, "gauge must leave Up after the breaker trips");
    assert!(snap.value("sea_tier_transitions_total").unwrap_or(0) >= 1);

    // Act 3: while the breaker is open, new files route around the dead
    // cache straight to the persist tier.
    for i in 0..8 {
        let fd = sea.create(&format!("/act3/f{i}.out")).unwrap();
        sea.write(fd, &payload(i + 16)).unwrap();
        sea.close(fd).unwrap();
    }
    for i in 0..8 {
        assert_eq!(
            sea.stat(&format!("/act3/f{i}.out")).unwrap().tier,
            "lustre",
            "breaker-open create must fall through to persist"
        );
    }

    // The tier heals; the prober re-admits it without any manual poke.
    core.tiers.get(0).set_down(false);
    let deadline = Instant::now() + Duration::from_secs(10);
    while core.health.state(0) != TierState::Up {
        assert!(Instant::now() < deadline, "prober never re-admitted the tier");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Drain: everything the run wrote is durable on persist, bytes exact.
    let (_stats, report) = sess.unmount();
    assert_eq!(report.errors, 0, "{report:?}");
    let persist = core.tiers.persist();
    for (act, base) in [("act1", 0usize), ("act2", 8), ("act3", 16)] {
        for i in 0..8 {
            let logical = format!("/{act}/f{i}.out");
            let got = std::fs::read(persist.physical(&logical))
                .unwrap_or_else(|e| panic!("{logical} not durable: {e}"));
            assert_eq!(got, payload(base + i), "{logical} corrupted");
        }
    }
}

#[test]
fn health_disabled_reproduces_fail_fast_behaviour() {
    let dir = tempdir("health-off");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(false, 3_600_000)
        .prefetcher(false)
        .health(false)
        .build();
    let sea = SeaIo::mount_with(cfg, flush_lists(), |t| t).unwrap();
    let core = sea.core().clone();

    for i in 0..4 {
        let fd = sea.create(&format!("/f{i}.out")).unwrap();
        sea.write(fd, &payload(i)).unwrap();
        sea.close(fd).unwrap();
    }
    core.tiers.get(0).set_down(true);

    // Old behaviour, exactly: each failed copy surfaces as a flush error
    // and charges the file's backoff budget; nothing degrades quietly.
    let report = flush_pass(&core, true);
    assert_eq!(report.errors, 4, "fail-fast must surface every copy error: {report:?}");
    assert_eq!(report.flushed + report.moved, 0, "{report:?}");

    // The disabled engine never moves off Up and never counts anything.
    assert_eq!(core.health.state(0), TierState::Up);
    assert_eq!(core.health.retries(), 0);
    assert_eq!(core.health.failovers(), 0);
    let snap = core.metrics_snapshot();
    assert_eq!(snap.value("sea_tier_transitions_total"), Some(0));

    // Placement still offers the downed tier (no health filter): the
    // next create lands on the cache exactly as the old code did.
    let fd = sea.create("/post.out").unwrap();
    sea.write(fd, b"post").unwrap();
    sea.close(fd).unwrap();
    assert_eq!(sea.stat("/post.out").unwrap().tier, "tmpfs");
}

#[test]
fn malformed_fault_spec_is_a_mount_time_bad_value() {
    for (spec, token) in [
        ("tier.fast=flaky:banana", "banana"),
        ("tier.fast=warp:3", "warp"),
        ("no-equals-here", "no-equals-here"),
    ] {
        let dir = tempdir("health-badspec");
        let cfg = SeaConfig::builder(dir.subdir("mount"))
            .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
            .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
            .faults(spec)
            .build();
        match SeaIo::mount_with(cfg, SeaLists::default(), |t| t) {
            Err(SeaError::BadValue(msg)) => assert!(
                msg.contains(token),
                "error for {spec:?} must name the offending token: {msg}"
            ),
            Err(e) => panic!("{spec:?} must fail mount with BadValue, got {e}"),
            Ok(_) => panic!("{spec:?} must fail the mount"),
        }
    }
}
