//! Integration: the three layers composed — AOT artifacts (L1 Pallas + L2
//! JAX) executed from the Rust coordinator (L3) over Sea-managed storage.
//! Skipped gracefully when `make artifacts` has not run.

use sea::config::{DatasetKind, PipelineKind, Strategy};
use sea::coordinator::compare_real;
use sea::dataset::bids::{generate_bids_tree, BidsLayout};
use sea::dataset::volume::synthetic_volume;
use sea::pipeline::executor::RealRunConfig;
use sea::runtime::{artifact_name, default_artifacts_dir, ComputeService};
use sea::testing::tempdir::tempdir;
use sea::util::{Rng, MIB};

fn have_artifacts() -> bool {
    let ok = default_artifacts_dir().join("manifest.tsv").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

#[test]
fn all_nine_artifacts_execute_with_sane_outputs() {
    if !have_artifacts() {
        return;
    }
    let (svc, _guard) = ComputeService::start(&default_artifacts_dir(), None).unwrap();
    let infos = svc.artifacts().unwrap();
    assert_eq!(infos.len(), 9);
    let mut rng = Rng::new(1);
    for info in infos {
        let (_h, voxels) = synthetic_volume(info.shape, &mut rng);
        let out = svc.preprocess(&info.name, voxels).unwrap();
        assert!(
            out.preprocessed.iter().all(|v| v.is_finite()),
            "{}: non-finite",
            info.name
        );
        assert!(out.mask.iter().all(|&m| m == 0.0 || m == 1.0), "{}", info.name);
        // the brain mask should cover a plausible fraction of the volume
        let frac = out.mask.iter().sum::<f32>() / out.mask.len() as f32;
        assert!(
            (0.05..0.95).contains(&frac),
            "{}: mask fraction {frac}",
            info.name
        );
    }
}

#[test]
fn pipelines_produce_distinct_outputs() {
    if !have_artifacts() {
        return;
    }
    let (svc, _guard) = ComputeService::start(&default_artifacts_dir(), None).unwrap();
    let mut rng = Rng::new(2);
    let shape = (16, 16, 32, 32); // hcp artifact shape
    let (_h, voxels) = synthetic_volume(shape, &mut rng);
    let afni = svc.preprocess("afni_hcp", voxels.clone()).unwrap();
    let spm = svc.preprocess("spm_hcp", voxels.clone()).unwrap();
    let fsl = svc.preprocess("fsl_hcp", voxels).unwrap();
    let diff = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
    };
    assert!(diff(&afni.preprocessed, &spm.preprocessed) > 1e-3);
    assert!(diff(&afni.preprocessed, &fsl.preprocessed) > 1e-3);
    assert!(diff(&spm.preprocessed, &fsl.preprocessed) > 1e-3);
}

#[test]
fn degraded_lustre_comparison_favours_sea_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let dir = tempdir("int-rt");
    let pristine = dir.subdir("dataset");
    generate_bids_tree(&pristine, &BidsLayout::scaled(DatasetKind::Hcp, 2), 5).unwrap();
    let mut cfg = RealRunConfig::new(
        &pristine,
        dir.subdir("scratch"),
        PipelineKind::Spm,
        DatasetKind::Hcp,
    );
    cfg.nprocs = 2;
    cfg.cache_capacity = 128 * MIB;
    cfg.lustre_bandwidth = Some(2.0 * MIB as f64);
    cfg.lustre_meta = Some(std::time::Duration::from_millis(2));
    let (svc, _guard) = ComputeService::start(
        &cfg.artifacts_dir,
        Some(vec![artifact_name(cfg.pipeline, cfg.dataset)]),
    )
    .unwrap();
    let cmp = compare_real(&pristine, dir.path(), &cfg, Strategy::Baseline, &svc).unwrap();
    assert!(
        cmp.speedup() > 1.2,
        "speedup {:.2} (base {:.2}s, sea {:.2}s)",
        cmp.speedup(),
        cmp.reference.total_secs(),
        cmp.sea.total_secs()
    );
    // Sea issued no data calls against the persistent tier during the run
    // (prefetch happens at mount; outputs stay in cache without flushing).
    assert_eq!(cmp.sea.stats.bytes_written_persist, 0);
}

#[test]
fn volume_round_trip_through_real_pipeline_files() {
    if !have_artifacts() {
        return;
    }
    // Verify the SNI1 files written by the executor parse back with the
    // artifact's shape.
    let dir = tempdir("int-rt2");
    let pristine = dir.subdir("dataset");
    generate_bids_tree(&pristine, &BidsLayout::scaled(DatasetKind::PreventAd, 1), 6)
        .unwrap();
    let mut cfg = RealRunConfig::new(
        &pristine,
        dir.subdir("scratch"),
        PipelineKind::Afni,
        DatasetKind::PreventAd,
    );
    cfg.flush_all = true;
    let (svc, _guard) = ComputeService::start(
        &cfg.artifacts_dir,
        Some(vec![artifact_name(cfg.pipeline, cfg.dataset)]),
    )
    .unwrap();
    let report = sea::pipeline::executor::run_real(&cfg, &svc).unwrap();
    assert!(report.flush.flushed + report.flush.moved >= 4);
    let preproc = pristine
        .join("derivatives/afni/sub-01/func/sub-01_task-rest_bold_preproc.sni");
    let (h, v) =
        sea::dataset::volume::read_volume(std::fs::File::open(&preproc).unwrap())
            .unwrap();
    assert_eq!(h.shape(), (8, 8, 16, 16));
    assert!(v.iter().all(|x| x.is_finite()));
}
