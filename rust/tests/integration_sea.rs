//! Integration: the real-mode Sea stack end to end — interception +
//! namespace + tiers + rules + flusher threads working together.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sea::config::SeaConfig;
use sea::flusher::SeaSession;
use sea::intercept::{OpenMode, SeaIo};
use sea::pathrules::{PathRules, SeaLists};
use sea::testing::tempdir::{tempdir, TempDirGuard};
use sea::util::MIB;

fn session(cache: u64, flush: &str, evict: &str, dir: &TempDirGuard) -> SeaSession {
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), cache)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(true, 20)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(flush).unwrap(),
        PathRules::parse(evict).unwrap(),
        PathRules::empty(),
    );
    SeaSession::start(cfg, lists, |t| t).unwrap()
}

#[test]
fn concurrent_workers_share_one_mount() {
    let dir = tempdir("int-concurrent");
    let sess = session(512 * MIB, r".*\.out$", r".*\.tmp$", &dir);
    let sea: &SeaIo = sess.io();

    std::thread::scope(|scope| {
        for w in 0..8 {
            scope.spawn(move || {
                for i in 0..20 {
                    let keep = format!("/w{w}/file-{i}.out");
                    let fd = sea.create(&keep).unwrap();
                    sea.write(fd, format!("w{w}i{i}").as_bytes()).unwrap();
                    sea.close(fd).unwrap();
                    let tmp = format!("/w{w}/scratch-{i}.tmp");
                    let fd = sea.create(&tmp).unwrap();
                    sea.write(fd, &[0u8; 256]).unwrap();
                    sea.close(fd).unwrap();
                }
            });
        }
    });

    // every keeper is readable with correct content
    for w in 0..8 {
        for i in 0..20 {
            let p = format!("/w{w}/file-{i}.out");
            let fd = sea.open(&p, OpenMode::Read).unwrap();
            let mut buf = [0u8; 16];
            let n = sea.read(fd, &mut buf).unwrap();
            assert_eq!(&buf[..n], format!("w{w}i{i}").as_bytes());
            sea.close(fd).unwrap();
        }
    }
    let (stats, report) = sess.unmount();
    assert_eq!(stats.create, 320);
    assert_eq!(report.flushed + report.moved, 160, "{report:?}");
    assert_eq!(report.evicted, 160);
}

#[test]
fn flusher_thread_keeps_up_during_writes() {
    let dir = tempdir("int-flusher");
    let sess = session(512 * MIB, ".*", "", &dir);
    let sea = sess.io();
    for i in 0..10 {
        let fd = sea.create(&format!("/out/vol-{i}.nii")).unwrap();
        sea.write(fd, &vec![i as u8; 64 * 1024]).unwrap();
        sea.close(fd).unwrap();
        std::thread::sleep(Duration::from_millis(25));
    }
    // background flusher has persisted most files already
    let persisted = sea.core().ns.files_on_tier(sea.core().tiers.persist_idx());
    assert!(persisted >= 5, "only {persisted} persisted in-flight");
    let (_stats, report) = sess.unmount();
    assert_eq!(report.flushed + report.moved, 10);
}

#[test]
fn cache_pressure_spills_without_data_loss() {
    let dir = tempdir("int-spill");
    // cache fits only ~2 files of 1 MiB
    let sess = session(2 * MIB + 100, "", "", &dir);
    let sea = sess.io();
    let payload: Vec<u8> = (0..MIB as usize).map(|i| (i % 251) as u8).collect();
    for i in 0..6 {
        let fd = sea.create(&format!("/d/big-{i}.dat")).unwrap();
        sea.write(fd, &payload).unwrap();
        sea.close(fd).unwrap();
    }
    // all six are intact wherever they landed
    let mut on_cache = 0;
    for i in 0..6 {
        let p = format!("/d/big-{i}.dat");
        let st = sea.stat(&p).unwrap();
        assert_eq!(st.size, MIB);
        if st.tier == "tmpfs" {
            on_cache += 1;
        }
        let fd = sea.open(&p, OpenMode::Read).unwrap();
        let mut buf = vec![0u8; MIB as usize];
        let mut off = 0;
        loop {
            let n = sea.read(fd, &mut buf[off..]).unwrap();
            if n == 0 {
                break;
            }
            off += n;
        }
        sea.close(fd).unwrap();
        assert_eq!(off, MIB as usize);
        assert_eq!(buf[1234], payload[1234]);
    }
    assert!(on_cache >= 1 && on_cache <= 2, "on_cache={on_cache}");
}

#[test]
fn throttled_persist_makes_sea_faster_than_baseline() {
    // The core claim, real mode: on a degraded "Lustre", writing through
    // Sea's cache is faster than writing directly.
    let make = |use_cache: bool| -> f64 {
        let dir = tempdir("int-thr");
        let mut b = SeaConfig::builder(dir.subdir("mount"));
        if use_cache {
            b = b.cache("tmpfs", dir.subdir("tmpfs"), 512 * MIB);
        }
        let cfg = b
            .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
            .flusher(false, 100)
            .build();
        let sess = SeaSession::start(cfg, SeaLists::default(), |t| {
            t.with_bandwidth_limit(4.0 * MIB as f64)
        })
        .unwrap();
        let sea = sess.io();
        let t0 = std::time::Instant::now();
        let payload = vec![1u8; 2 * MIB as usize];
        for i in 0..3 {
            let fd = sea.create(&format!("/out/f{i}")).unwrap();
            sea.write(fd, &payload).unwrap();
            sea.close(fd).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        sess.unmount();
        dt
    };
    let baseline = make(false);
    let with_sea = make(true);
    assert!(
        baseline > 3.0 * with_sea,
        "baseline={baseline:.2}s sea={with_sea:.2}s"
    );
}

#[test]
fn prefetch_then_update_never_touches_persist() {
    let dir = tempdir("int-prefetch");
    let lustre = dir.subdir("lustre");
    std::fs::create_dir_all(lustre.join("inputs")).unwrap();
    std::fs::write(lustre.join("inputs/scan.nii"), vec![3u8; 4096]).unwrap();
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
        .persist("lustre", &lustre, 100_000 * MIB)
        .flusher(false, 100)
        .build();
    let lists = SeaLists::new(
        PathRules::empty(),
        PathRules::empty(),
        PathRules::parse(r".*inputs/.*").unwrap(),
    );
    let sess = SeaSession::start(cfg, lists, |t| t).unwrap();
    let sea = sess.io();
    // SPM-style in-place update through the prefetched replica
    let fd = sea.open("/inputs/scan.nii", OpenMode::ReadWrite).unwrap();
    for _ in 0..50 {
        sea.write(fd, &[9u8; 64]).unwrap();
    }
    sea.close(fd).unwrap();
    let stats = sea.stats();
    assert_eq!(stats.bytes_written_persist, 0);
    assert_eq!(stats.bytes_written_cache, 50 * 64);
    // original content on "Lustre" untouched
    let on_lustre = std::fs::read(lustre.join("inputs/scan.nii")).unwrap();
    assert_eq!(on_lustre, vec![3u8; 4096]);
    sess.unmount();
}

#[test]
fn prefetch_staging_into_undersized_cache_evicts_cold_replicas() {
    // The evict-to-make-room acceptance scenario: a cache deliberately
    // sized for two volumes, four persist-resident volumes promoted one
    // after another through the live prefetcher thread. The seed
    // behaviour skipped staging once the cache filled; now each new
    // promotion evicts the coldest clean replica (its persist copy
    // survives) and staging completes for every volume.
    let dir = tempdir("int-evict-staging");
    let lustre = dir.subdir("lustre");
    std::fs::create_dir_all(&lustre).unwrap();
    for i in 0..4u8 {
        std::fs::write(lustre.join(format!("v{i}.nii")), vec![i; 600]).unwrap();
    }
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 1400) // fits 2 of 4 volumes
        .persist("lustre", &lustre, 100_000 * MIB)
        .flusher(false, 100)
        .readahead(0) // promote-on-read only: deterministic order
        .build();
    let sess = SeaSession::start(cfg, SeaLists::default(), |t| t).unwrap();
    let sea = sess.io();

    let read_whole = |path: &str| {
        let fd = sea.open(path, OpenMode::Read).unwrap();
        let mut buf = [0u8; 256];
        let mut total = 0usize;
        loop {
            let n = sea.read(fd, &mut buf).unwrap();
            if n == 0 {
                break;
            }
            total += n;
        }
        sea.close(fd).unwrap();
        assert_eq!(total, 600, "{path}");
    };
    let wait_cached = |path: &str| {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if sea.stat(path).unwrap().tier == "tmpfs" {
                return;
            }
            assert!(
                Instant::now() < deadline,
                "{path} never staged — admission skipped instead of evicting"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    };

    // v0, v1 fit outright; v2 and v3 each need the prefetcher to evict
    // the coldest cached replica first.
    for p in ["/v0.nii", "/v1.nii", "/v2.nii", "/v3.nii"] {
        read_whole(p);
        wait_cached(p);
    }

    let core = sess.io().core().clone();
    // exactly two volumes fit: the two hottest are cached, the evicted
    // ones fell back to their persisted copies
    assert_eq!(sea.stat("/v3.nii").unwrap().tier, "tmpfs");
    assert_eq!(core.tiers.get(0).used(), 2 * 600);
    let cached: Vec<String> = (0..4)
        .map(|i| format!("/v{i}.nii"))
        .filter(|p| sea.stat(p).unwrap().tier == "tmpfs")
        .collect();
    assert_eq!(cached.len(), 2, "{cached:?}");
    let adm = core.admission.snapshot();
    assert!(adm.evicted_to_fit >= 2, "{adm:?}");
    assert!(adm.evicted_files >= 2, "{adm:?}");
    // No data was lost: every volume still reads back byte-for-byte.
    // These reads re-trigger promotions, so each open can race the
    // prefetcher evicting the very replica it resolved — `SeaIo::open`
    // must fall back to the surviving persist replica, never error.
    for i in 0..4u8 {
        let p = format!("/v{i}.nii");
        let fd = sea.open(&p, OpenMode::Read).unwrap();
        let mut buf = vec![0u8; 1024];
        let n = sea.read(fd, &mut buf).unwrap();
        assert_eq!(&buf[..n], vec![i; 600].as_slice(), "{p}");
        sea.close(fd).unwrap();
    }
    sess.unmount();
}

#[test]
fn sea_ini_file_round_trip_drives_session() {
    // Full config-file path: write sea.ini + lists, mount from files.
    let dir = tempdir("int-ini");
    let lustre = dir.subdir("lustre");
    let tmpfs = dir.subdir("tmpfs");
    let flushlist = dir.path().join(".sea_flushlist");
    std::fs::write(&flushlist, ".*\\.out$\n").unwrap();
    let ini = format!(
        "mount = {}\n[caches]\ncache = tmpfs:{}:64M\npersist = lustre:{}:1G\n\
         [lists]\nflushlist = {}\n[flusher]\nenabled = true\ninterval_ms = 10\n",
        dir.path().join("mount").display(),
        tmpfs.display(),
        lustre.display(),
        flushlist.display(),
    );
    let ini_path = dir.path().join("sea.ini");
    std::fs::write(&ini_path, ini).unwrap();

    let cfg = SeaConfig::load(&ini_path).unwrap();
    assert_eq!(cfg.caches.len(), 1);
    let sea = SeaIo::mount(cfg).unwrap();
    let fd = sea.create("/r/x.out").unwrap();
    sea.write(fd, b"ok").unwrap();
    sea.close(fd).unwrap();
    let rep = sea::flusher::drain(sea.core());
    assert_eq!(rep.flushed, 1);
    assert!(lustre.join("r/x.out").exists());
}

#[test]
fn mountpoint_view_is_consistent_across_tiers() {
    let dir = tempdir("int-view");
    let lustre = dir.subdir("lustre");
    std::fs::create_dir_all(lustre.join("pre")).unwrap();
    std::fs::write(lustre.join("pre/existing.nii"), b"x").unwrap();
    let sess = session(64 * MIB, "", "", &dir);
    drop(sess);
    // remount over a lustre dir that has data
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs2"), 64 * MIB)
        .persist("lustre", &lustre, 100_000 * MIB)
        .build();
    let sea = Arc::new(SeaIo::mount_with(cfg, SeaLists::default(), |t| t).unwrap());
    // new file goes to cache, existing is on persist — one merged view
    let fd = sea.create("/pre/new.nii").unwrap();
    sea.close(fd).unwrap();
    let names = sea.readdir("/pre").unwrap();
    assert_eq!(names, vec!["existing.nii", "new.nii"]);
}
