//! Integration: simulation mode across modules — cluster resources, busy
//! writers, page cache, trace replay and the experiment runner.

use sea::config::{ClusterConfig, DatasetKind, PipelineKind, Strategy, WorkloadSpec};
use sea::experiments::run_cell;
use sea::stats;

fn speedup(cluster: &ClusterConfig, spec: &WorkloadSpec) -> f64 {
    let b = run_cell(cluster, &spec.clone().strategy(Strategy::Baseline)).unwrap();
    let s = run_cell(cluster, &spec.clone().strategy(Strategy::Sea)).unwrap();
    b.makespan / s.makespan
}

#[test]
fn headline_cell_speedup_in_paper_range() {
    // SPM × 1 HCP image × 6 busy writers: paper avg 12.6x, max 32x.
    let cluster = ClusterConfig::dedicated();
    let spec = WorkloadSpec::new(PipelineKind::Spm, DatasetKind::Hcp, 1).busy_writers(6);
    let s = speedup(&cluster, &spec);
    assert!((5.0..40.0).contains(&s), "headline speedup {s:.1}");
}

#[test]
fn dataset_ordering_matches_paper() {
    // §2.2: HCP speedups > ds001545 > PREVENT-AD (largest images win),
    // averaged over pipelines, 1 process, 6 busy writers.
    let cluster = ClusterConfig::dedicated();
    let avg = |d: DatasetKind| {
        let v: Vec<f64> = PipelineKind::ALL
            .iter()
            .map(|p| speedup(&cluster, &WorkloadSpec::new(*p, d, 1).busy_writers(6)))
            .collect();
        stats::mean(&v)
    };
    let hcp = avg(DatasetKind::Hcp);
    let ds = avg(DatasetKind::Ds001545);
    let pad = avg(DatasetKind::PreventAd);
    assert!(hcp > ds, "hcp={hcp:.2} ds={ds:.2}");
    assert!(ds > pad, "ds={ds:.2} pad={pad:.2}");
}

#[test]
fn flush_enabled_costs_but_persists() {
    let cluster = ClusterConfig::beluga();
    let spec = WorkloadSpec::new(PipelineKind::Afni, DatasetKind::Ds001545, 8);
    let plain = run_cell(&cluster, &spec.clone()).unwrap();
    let flushed = run_cell(&cluster, &spec.clone().flush(true)).unwrap();
    assert!(flushed.makespan >= plain.makespan);
    assert!(flushed.metrics.files_to_lustre > 0);
    assert_eq!(plain.metrics.files_to_lustre, 0);
}

#[test]
fn busy_writers_monotonically_degrade_baseline() {
    let cluster = ClusterConfig::dedicated();
    let mk = |busy| {
        run_cell(
            &cluster,
            &WorkloadSpec::new(PipelineKind::Afni, DatasetKind::Hcp, 1)
                .busy_writers(busy)
                .strategy(Strategy::Baseline),
        )
        .unwrap()
        .makespan
    };
    let m0 = mk(0);
    let m3 = mk(3);
    let m6 = mk(6);
    assert!(m3 > m0, "m0={m0} m3={m3}");
    assert!(m6 > m3, "m3={m3} m6={m6}");
}

#[test]
fn sea_insensitive_to_busy_writers_without_flush() {
    // Sea writes to tmpfs, so busy writers barely matter (reads aside).
    let cluster = ClusterConfig::dedicated();
    let mk = |busy| {
        run_cell(
            &cluster,
            &WorkloadSpec::new(PipelineKind::Spm, DatasetKind::Hcp, 1).busy_writers(busy),
        )
        .unwrap()
        .makespan
    };
    let calm = mk(0);
    let degraded = mk(6);
    assert!(
        degraded < 1.5 * calm,
        "sea degraded too much: {calm:.0}s -> {degraded:.0}s"
    );
}

#[test]
fn production_cluster_less_affected_than_dedicated() {
    // Beluga's OSTs are ~7x faster; the same 6 busy-writer load hurts less.
    let spec = WorkloadSpec::new(PipelineKind::Spm, DatasetKind::Hcp, 1)
        .busy_writers(6);
    let ded = speedup(&ClusterConfig::dedicated(), &spec);
    let prod = speedup(&ClusterConfig::beluga(), &spec);
    assert!(ded > prod, "dedicated={ded:.2} production={prod:.2}");
}

#[test]
fn repeated_runs_vary_but_agree_in_sign() {
    let cluster = ClusterConfig::dedicated();
    let mut speedups = Vec::new();
    for seed in 0..5u64 {
        let spec = WorkloadSpec::new(PipelineKind::Afni, DatasetKind::PreventAd, 1)
            .busy_writers(6)
            .seed(seed * 1231);
        speedups.push(speedup(&cluster, &spec));
    }
    let s = stats::summarize(&speedups);
    assert!(s.min > 1.2, "{speedups:?}");
    assert!(s.std > 0.0, "jitter should produce variance: {speedups:?}");
}

#[test]
fn tmpfs_runs_are_busy_writer_invariant() {
    let cluster = ClusterConfig::dedicated();
    let mk = |busy| {
        run_cell(
            &cluster,
            &WorkloadSpec::new(PipelineKind::FslFeat, DatasetKind::Ds001545, 1)
                .busy_writers(busy)
                .strategy(Strategy::Tmpfs),
        )
        .unwrap()
        .makespan
    };
    let a = mk(0);
    let b = mk(6);
    assert!((a - b).abs() / a < 0.02, "tmpfs affected by busy writers: {a} vs {b}");
}

#[test]
fn metrics_account_for_strategy() {
    let cluster = ClusterConfig::dedicated();
    let spec = WorkloadSpec::new(PipelineKind::Afni, DatasetKind::Ds001545, 1);
    let base = run_cell(&cluster, &spec.clone().strategy(Strategy::Baseline)).unwrap();
    let seam = run_cell(&cluster, &spec.clone().strategy(Strategy::Sea)).unwrap();
    // Baseline pushes output bytes to lustre (page cache + writeback);
    // Sea keeps them in cache.
    assert!(base.metrics.lustre_write_bytes > 1e8);
    assert!(seam.metrics.cache_write_bytes > 1e8);
    assert_eq!(seam.metrics.files_to_lustre, 0);
    // glibc accounting mirrors Table 2 magnitudes
    assert!(base.metrics.total_calls > 250_000);
    assert!(base.metrics.lustre_calls > 3_000);
}
