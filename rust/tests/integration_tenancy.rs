//! PR-10 acceptance tests for the multi-tenant control plane.
//!
//! Five pins:
//!
//! 1. A config with no `[tenants]` section reproduces the single-tenant
//!    behaviour exactly: no per-tenant metric families, no quota checks,
//!    `multi_tenant: false` on the status endpoint.
//! 2. Quota caps hold: an over-quota write growth falls through to the
//!    persist tier error-free (the same degraded path as a breaker-open
//!    tier) and the tenant's `fell_through` counter records it; a
//!    cross-tenant rename moves the cache accounting between owners.
//! 3. `GET /status`, `GET /tenants/<id>` and `POST /tenants/<id>/quota`
//!    work against a live mount — a quota raise takes effect on the very
//!    next placement, without a remount.
//! 4. Concurrent `/metrics` + `/status` scrapes stay consistent (all
//!    200s, parseable bodies) while 8 writer threads hammer the mount.
//! 5. A noisy tenant's background storm cannot push a victim tenant's
//!    foreground p99 wait above 2x its solo baseline when per-tenant QoS
//!    lanes are on.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sea::config::SeaConfig;
use sea::coordinator::serve_ops;
use sea::flusher::SeaSession;
use sea::intercept::SeaIo;
use sea::pathrules::{PathRules, SeaLists};
use sea::sched::IoClass;
use sea::testing::tempdir::tempdir;
use sea::util::{KIB, MIB};

fn flush_lists() -> SeaLists {
    SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    )
}

fn payload(len: usize, seed: u8) -> Vec<u8> {
    (0..len).map(|b| (b as u8).wrapping_mul(seed | 1)).collect()
}

/// Minimal raw-HTTP client against the ops server: returns
/// `(status_code, body)`.
fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut conn = TcpStream::connect(addr).expect("ops server reachable");
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: sea\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    conn.read_to_string(&mut raw).expect("read response");
    let (head, response_body) = raw.split_once("\r\n\r\n").expect("http head");
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    (status, response_body.to_string())
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
    http(addr, "GET", path, "")
}

// ---------------------------------------------------------------------------
// 1. No [tenants] section => byte-for-byte single-tenant behaviour.
// ---------------------------------------------------------------------------

#[test]
fn absent_tenants_section_reproduces_single_tenant_behaviour() {
    let dir = tempdir("tenancy-single");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(false, 3_600_000)
        .prefetcher(false)
        .build();
    let sea = SeaIo::mount_with(cfg, flush_lists(), |t| t).unwrap();
    let core = sea.core().clone();
    assert!(!core.tenants.multi());

    for i in 0..4 {
        let fd = sea.create(&format!("/proj/f{i}.out")).unwrap();
        sea.write(fd, &payload(4 * KIB as usize, i as u8)).unwrap();
        sea.close(fd).unwrap();
        assert_eq!(sea.stat(&format!("/proj/f{i}.out")).unwrap().tier, "tmpfs");
    }

    // The registry stays inert: everything resolves to the default
    // tenant and no accounting is performed.
    let snap = core.tenants.snapshot(0);
    assert_eq!(snap.cache_used, 0, "single-tenant mount must not account");
    assert_eq!(snap.files, 0);

    // No per-tenant metric families and no tenant block on /status.
    let prom = core.metrics_snapshot().to_prometheus();
    assert!(
        !prom.contains("sea_tenant_"),
        "single-tenant /metrics grew tenant families:\n{prom}"
    );
    let status = core.status_json();
    assert!(
        status.contains("\"multi_tenant\": false"),
        "status: {status}"
    );
}

// ---------------------------------------------------------------------------
// 2. Quota caps hold; over-quota growth falls through to persist.
// ---------------------------------------------------------------------------

#[test]
fn quota_cap_holds_and_over_quota_writes_fall_through_to_persist() {
    let dir = tempdir("tenancy-quota");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(false, 3_600_000)
        .prefetcher(false)
        .tenant("alice", "/alice", Some(64 * KIB))
        .tenant("bob", "/bob", None)
        .build();
    let sea = SeaIo::mount_with(cfg, flush_lists(), |t| t).unwrap();
    let core = sea.core().clone();
    assert!(core.tenants.multi());
    let alice = core.tenants.resolve("/alice/x");
    let bob = core.tenants.resolve("/bob/x");
    assert_ne!(alice, 0);
    assert_ne!(bob, 0);
    assert_ne!(alice, bob);

    // 10 x 6 KiB = 60 KiB: inside the 64 KiB quota, all cache-resident.
    let chunk = 6 * KIB as usize;
    for i in 0..10 {
        let fd = sea.create(&format!("/alice/f{i}.out")).unwrap();
        sea.write(fd, &payload(chunk, i as u8)).unwrap();
        sea.close(fd).unwrap();
        assert_eq!(sea.stat(&format!("/alice/f{i}.out")).unwrap().tier, "tmpfs");
    }
    assert_eq!(core.tenants.snapshot(alice).cache_used, 60 * KIB);

    // The 11th file's growth would hit 66 KiB > 64 KiB: the write must
    // succeed by falling through to persist, exactly like a full tier.
    let fd = sea.create("/alice/f10.out").unwrap();
    sea.write(fd, &payload(chunk, 11)).unwrap();
    sea.close(fd).unwrap();
    assert_eq!(
        sea.stat("/alice/f10.out").unwrap().tier,
        "lustre",
        "over-quota growth must land on persist, not error"
    );
    let snap = core.tenants.snapshot(alice);
    assert!(snap.fell_through >= 1, "fall-through not counted: {snap:?}");
    assert!(
        snap.cache_used <= 64 * KIB,
        "quota breached: {} used",
        snap.cache_used
    );

    // Persist-tier writes are never charged against the quota.
    assert_eq!(snap.cache_used, 60 * KIB);

    // A cross-tenant rename hands the cache accounting to the new owner.
    sea.rename("/alice/f0.out", "/bob/f0.out").unwrap();
    assert_eq!(core.tenants.snapshot(alice).cache_used, 54 * KIB);
    assert_eq!(core.tenants.snapshot(bob).cache_used, 6 * KIB);
    assert_eq!(sea.stat("/bob/f0.out").unwrap().tier, "tmpfs");

    // With 6 KiB of headroom back, alice can cache one more file.
    let fd = sea.create("/alice/f11.out").unwrap();
    sea.write(fd, &payload(chunk, 12)).unwrap();
    sea.close(fd).unwrap();
    assert_eq!(sea.stat("/alice/f11.out").unwrap().tier, "tmpfs");
}

// ---------------------------------------------------------------------------
// 3. Live ops API: status, tenant detail, quota update without remount.
// ---------------------------------------------------------------------------

#[test]
fn ops_api_serves_status_and_applies_quota_updates_live() {
    let dir = tempdir("tenancy-ops");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(false, 3_600_000)
        .prefetcher(false)
        .tenant("alice", "/alice", Some(4 * KIB))
        .ops_bind("127.0.0.1:0")
        .build();
    let sess = SeaSession::start(cfg, flush_lists(), |t| t).unwrap();
    let sea = sess.io();
    let addr = sess.ops_addr().expect("ops server bound");

    let (code, status) = http_get(addr, "/status");
    assert_eq!(code, 200, "{status}");
    assert!(status.contains("\"multi_tenant\": true"), "{status}");
    assert!(status.contains("\"name\": \"alice\""), "{status}");
    assert!(status.contains("\"tiers\""), "{status}");

    let (code, detail) = http_get(addr, "/tenants/alice");
    assert_eq!(code, 200, "{detail}");
    assert!(detail.contains("\"quota_bytes\": 4096"), "{detail}");

    let (code, _) = http_get(addr, "/tenants/nosuch");
    assert_eq!(code, 404);

    // 8 KiB > the 4 KiB quota: the write falls through to persist.
    let fd = sea.create("/alice/before.out").unwrap();
    sea.write(fd, &payload(8 * KIB as usize, 1)).unwrap();
    sea.close(fd).unwrap();
    assert_eq!(sea.stat("/alice/before.out").unwrap().tier, "lustre");

    // Raise the quota over the wire; no remount.
    let (code, updated) = http(addr, "POST", "/tenants/alice/quota", "1MiB");
    assert_eq!(code, 200, "{updated}");
    assert!(updated.contains("\"quota_bytes\": 1048576"), "{updated}");

    // The very next placement sees the new cap and stays on cache.
    let fd = sea.create("/alice/after.out").unwrap();
    sea.write(fd, &payload(8 * KIB as usize, 2)).unwrap();
    sea.close(fd).unwrap();
    assert_eq!(
        sea.stat("/alice/after.out").unwrap().tier,
        "tmpfs",
        "quota raise must apply without a remount"
    );

    let (code, body) = http(addr, "POST", "/tenants/alice/quota", "garbage");
    assert_eq!(code, 400, "{body}");

    // CI artifact hook: archive the /status body of this live run so the
    // control-plane job uploads a real capture, not a synthetic fixture.
    if let Some(out) = std::env::var_os("SEA_STATUS_ARTIFACT") {
        let (code, status) = http_get(addr, "/status");
        assert_eq!(code, 200);
        std::fs::write(out, status).unwrap();
    }
    sess.unmount();
}

// ---------------------------------------------------------------------------
// 4. Concurrent /metrics + /status scrapes during an active 8-thread run.
// ---------------------------------------------------------------------------

#[test]
fn concurrent_scrapes_stay_consistent_during_active_run() {
    let dir = tempdir("tenancy-scrape");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 256 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(false, 3_600_000)
        .prefetcher(false)
        .tenant("alice", "/alice", None)
        .tenant("bob", "/bob", None)
        .build();
    let sea = Arc::new(SeaIo::mount_with(cfg, flush_lists(), |t| t).unwrap());
    let server = serve_ops("127.0.0.1:0", sea.core().clone()).unwrap();
    let addr = server.addr();

    let stop = Arc::new(AtomicBool::new(false));
    let mut writers = Vec::new();
    for t in 0..8 {
        let sea = sea.clone();
        let stop = stop.clone();
        writers.push(std::thread::spawn(move || {
            let ns = if t % 2 == 0 { "alice" } else { "bob" };
            let mut i = 0usize;
            while !stop.load(Ordering::Acquire) {
                let path = format!("/{ns}/w{t}/f{i}.out");
                let fd = sea.create(&path).unwrap();
                sea.write(fd, &payload(4 * KIB as usize, t as u8)).unwrap();
                sea.close(fd).unwrap();
                i += 1;
            }
            i
        }));
    }

    let scrapes = Arc::new(AtomicU64::new(0));
    let mut scrapers = Vec::new();
    for s in 0..2 {
        let stop = stop.clone();
        let scrapes = scrapes.clone();
        scrapers.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                let path = if s == 0 { "/metrics" } else { "/status" };
                let (code, body) = http_get(addr, path);
                assert_eq!(code, 200, "{path} failed mid-run: {body}");
                if s == 0 {
                    assert!(body.contains("sea_tenant_cache_used_bytes"), "{body}");
                } else {
                    assert!(body.contains("\"multi_tenant\": true"), "{body}");
                }
                scrapes.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }

    std::thread::sleep(Duration::from_millis(300));
    stop.store(true, Ordering::Release);
    let written: usize = writers.into_iter().map(|h| h.join().unwrap()).sum();
    for h in scrapers {
        h.join().unwrap();
    }
    assert!(written > 0);
    assert!(
        scrapes.load(Ordering::Relaxed) >= 4,
        "scrapers barely ran: {} scrapes",
        scrapes.load(Ordering::Relaxed)
    );

    // After the dust settles the accounting still mirrors the tiers:
    // the sum of per-tenant cache_used equals the cache tier's usage.
    let core = sea.core().clone();
    let tenant_total: u64 = core
        .tenants
        .snapshots()
        .iter()
        .map(|s| s.cache_used)
        .sum();
    let tier_used: u64 = core.tiers.caches().iter().map(|t| t.used()).sum();
    assert_eq!(tenant_total, tier_used, "accounting drifted from tiers");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// 5. Noisy-neighbor isolation: background storm vs foreground p99.
// ---------------------------------------------------------------------------

fn p99_us(mut lat_us: Vec<f64>) -> f64 {
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((lat_us.len() as f64 * 0.99).ceil() as usize).min(lat_us.len()) - 1;
    lat_us[idx]
}

#[test]
fn noisy_tenant_storm_cannot_double_victim_foreground_p99() {
    const BW: f64 = 8.0 * 1024.0 * 1024.0; // 8 MiB/s persist limit
    const FG_CHUNK: u64 = 128 * KIB; // ~16 ms of tokens per wait
    const BG_CHUNK: u64 = 16 * KIB; // ~2 ms stolen per lane escape
    const ITERS: usize = 100;

    let dir = tempdir("tenancy-noisy");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(false, 3_600_000)
        .prefetcher(false)
        .sched_qos(true)
        .tenant("noisy", "/noisy", None)
        .tenant("victim", "/victim", None)
        .build();
    let sea = SeaIo::mount_with(cfg, flush_lists(), |t| {
        t.with_bandwidth_limit(BW)
    })
    .unwrap();
    let core = sea.core().clone();
    let persist = core.tiers.persist_idx();
    let noisy = core.tenants.resolve("/noisy/x");
    let victim = core.tenants.resolve("/victim/x");

    // Solo baseline: the victim's foreground waits are token-limited at
    // ~FG_CHUNK/BW each once the burst allowance drains.
    let mut solo = Vec::with_capacity(ITERS);
    for _ in 0..ITERS {
        let t0 = Instant::now();
        core.tiers
            .get(persist)
            .wait_data_tagged(FG_CHUNK, IoClass::Foreground, victim);
        solo.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let p99_solo = p99_us(solo);

    // Storm: two noisy-tenant threads hammer the same tier with
    // background-class requests through the noisy lane.
    let stop = Arc::new(AtomicBool::new(false));
    let mut storm = Vec::new();
    for _ in 0..2 {
        let core = core.clone();
        let stop = stop.clone();
        storm.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                core.tiers
                    .get(persist)
                    .wait_data_tagged(BG_CHUNK, IoClass::Background, noisy);
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(300));

    let mut under_storm = Vec::with_capacity(ITERS);
    for _ in 0..ITERS {
        let t0 = Instant::now();
        core.tiers
            .get(persist)
            .wait_data_tagged(FG_CHUNK, IoClass::Foreground, victim);
        under_storm.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let p99_storm = p99_us(under_storm);
    stop.store(true, Ordering::Release);
    for h in storm {
        h.join().unwrap();
    }

    // The QoS lanes must keep the victim within 2x its solo baseline
    // (floored at 2 ms so timer jitter on an idle bucket cannot turn
    // the bound degenerate).
    let bound = 2.0 * p99_solo.max(2_000.0);
    assert!(
        p99_storm <= bound,
        "victim p99 {p99_storm:.0} us exceeds 2x solo baseline {p99_solo:.0} us"
    );

    // The noisy tenant was really shaped: its lane saw traffic and
    // burned yield slices while the victim was waiting.
    let (bg_bytes, yields) = core
        .tiers
        .get(persist)
        .lane_snapshot(noisy)
        .expect("noisy lane installed");
    assert!(bg_bytes > 0, "storm never drew from its lane");
    assert!(yields > 0, "storm never yielded to foreground");
}
