//! Concurrency stress + regression tests for the lock-sharded hot path.
//!
//! The interceptor used to serialise every call on one global fd-table
//! mutex held *across* physical I/O and throttle sleeps. These tests pin
//! the two properties the sharded design must provide:
//!
//! 1. N threads doing create/write/read/close through one mount keep the
//!    tier-reservation and call-counter invariants intact while the
//!    background flusher runs;
//! 2. a cache-tier read completes promptly while a throttled persist-tier
//!    write is mid-flight on another fd (the regression the old global
//!    lock caused: every worker stalled behind one throttled write).
//!
//! Plus the transfer-fence regressions (both seed-inherited ROADMAP
//! windows): a rename racing an in-flight flush copy must not strand a
//! persist copy at the stale path, and an unlink+recreate racing one
//! must not interleave bytes of two incarnations on the persist tier.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Barrier;
use std::time::{Duration, Instant};

use sea::config::SeaConfig;
use sea::flusher::{drain, flush_pass, SeaSession};
use sea::intercept::{OpenMode, SeaError, SeaIo};
use sea::pathrules::{PathRules, SeaLists};
use sea::testing::tempdir::tempdir;
use sea::util::MIB;

/// No interrupted-transfer temp file may survive under `root`.
fn assert_no_temp_litter(root: &Path) {
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        if let Ok(entries) = std::fs::read_dir(&dir) {
            for e in entries.flatten() {
                let p = e.path();
                if p.is_dir() {
                    stack.push(p);
                } else {
                    assert!(
                        !sea::transfer::is_temp_name(&e.file_name().to_string_lossy()),
                        "transfer temp litter: {p:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn stress_invariants_hold_under_concurrent_io_with_flusher() {
    const WORKERS: usize = 8;
    const ITERS: usize = 50;

    let dir = tempdir("stress-conc");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(true, 5)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::parse(r".*\.tmp$").unwrap(),
        PathRules::empty(),
    );
    let sess = SeaSession::start(cfg, lists, |t| t).unwrap();
    let sea = sess.io();

    std::thread::scope(|s| {
        for w in 0..WORKERS {
            s.spawn(move || {
                for i in 0..ITERS {
                    let keep = format!("/w{w}/r{i}.out");
                    let fd = sea.create(&keep).unwrap();
                    sea.write(fd, format!("data-{w}-{i}").as_bytes()).unwrap();
                    sea.close(fd).unwrap();

                    let tmp = format!("/w{w}/s{i}.tmp");
                    let fd = sea.create(&tmp).unwrap();
                    sea.write(fd, &[w as u8; 512]).unwrap();
                    sea.close(fd).unwrap();
                    if i % 2 == 0 {
                        sea.unlink(&tmp).unwrap();
                    }

                    let fd = sea.open(&keep, OpenMode::Read).unwrap();
                    let mut buf = [0u8; 32];
                    let n = sea.read(fd, &mut buf).unwrap();
                    assert_eq!(&buf[..n], format!("data-{w}-{i}").as_bytes());
                    sea.close(fd).unwrap();
                }
            });
        }
    });

    let stats = sea.stats();
    assert_eq!(stats.create as usize, WORKERS * ITERS * 2);
    assert_eq!(stats.unlink as usize, WORKERS * ITERS / 2);
    assert_eq!(stats.write as usize, WORKERS * ITERS * 2);

    let core = sess.io().core().clone();
    let (_stats, report) = sess.unmount();
    assert_eq!(report.errors, 0, "no flush may fail: {report:?}");

    // Every .out file was persisted by the drain and survives in the
    // namespace; every .tmp file is gone (unlinked or drain-evicted).
    let persist_idx = core.tiers.persist_idx();
    for w in 0..WORKERS {
        for i in 0..ITERS {
            let keep = format!("/w{w}/r{i}.out");
            let on_disk = core.tiers.persist().physical(&keep);
            assert!(on_disk.exists(), "{keep} missing on persist tier");
            assert!(
                core.ns.with_meta(&keep, |m| m.has_replica(persist_idx)).unwrap(),
                "{keep} lacks persist replica"
            );
        }
    }
    for path in core.ns.all_paths() {
        assert!(!path.ends_with(".tmp"), "{path} survived drain");
    }

    // Reservation invariant: each cache tier's accounted usage equals the
    // total size of replicas the namespace still records there.
    for tier_idx in 0..core.tiers.persist_idx() {
        let mut expected = 0u64;
        for path in core.ns.all_paths() {
            core.ns.with_meta(&path, |m| {
                if m.has_replica(tier_idx) {
                    expected += m.size();
                }
            });
        }
        assert_eq!(
            core.tiers.get(tier_idx).used(),
            expected,
            "tier {tier_idx} reservation drifted from namespace contents"
        );
    }
}

#[test]
fn trace_event_counts_match_call_stats_under_contention() {
    // The instrumentation invariant the obs wrapper pattern promises:
    // every public call records exactly one histogram sample and one
    // trace event (or a counted drop), so per-kind totals from the
    // observability layer equal the CallStats counters even with 8
    // threads hammering one mount.
    const WORKERS: usize = 8;
    const ITERS: usize = 40;

    use sea::obs::EventKind;

    let dir = tempdir("obs-stress");
    let trace = dir.path().join("stress.trace");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .obs_trace_path(&trace)
        .build();
    let sea = SeaIo::mount_with(cfg, SeaLists::default(), |t| t).unwrap();
    {
        let sea = &sea;
        std::thread::scope(|s| {
            for w in 0..WORKERS {
                s.spawn(move || {
                    for i in 0..ITERS {
                        let p = format!("/w{w}/f{i}.dat");
                        let fd = sea.create(&p).unwrap();
                        sea.write(fd, &[w as u8; 256]).unwrap();
                        sea.close(fd).unwrap();
                        let fd = sea.open(&p, OpenMode::Read).unwrap();
                        let mut buf = [0u8; 256];
                        sea.read(fd, &mut buf).unwrap();
                        sea.close(fd).unwrap();
                        sea.stat(&p).unwrap();
                        if i % 2 == 0 {
                            sea.unlink(&p).unwrap();
                        }
                    }
                });
            }
        });
    }
    let stats = sea.stats();
    let obs = sea.core().obs.clone();

    // Histograms are never dropped: per-kind sample counts are exact.
    for (kind, expected) in [
        (EventKind::Create, stats.create),
        (EventKind::Write, stats.write),
        (EventKind::Open, stats.open),
        (EventKind::Read, stats.read),
        (EventKind::Close, stats.close),
        (EventKind::Stat, stats.stat),
        (EventKind::Unlink, stats.unlink),
    ] {
        assert_eq!(
            obs.hist_count(kind),
            expected,
            "histogram count for {} drifted from CallStats",
            kind.as_str()
        );
    }

    // Unmount: the drainer joins and leaves a complete trace file.
    drop(sea);
    let recorded = obs.trace_recorded();
    let dropped = obs.trace_dropped();
    assert!(
        recorded + dropped >= stats.total(),
        "ring accounting lost events: {recorded} recorded + {dropped} dropped < {} calls",
        stats.total()
    );
    let events = sea::obs::trace::read_trace(&trace).unwrap();
    assert_eq!(
        events.len() as u64,
        recorded,
        "on-disk trace disagrees with the recorded counter"
    );
    if dropped == 0 {
        // Nothing overflowed (plenty of ring for this workload), so the
        // file's per-kind event counts equal CallStats exactly.
        for (kind, expected) in
            [(EventKind::Write, stats.write), (EventKind::Unlink, stats.unlink)]
        {
            let n = events.iter().filter(|e| e.kind() == Some(kind)).count() as u64;
            assert_eq!(n, expected, "traced {} events != CallStats", kind.as_str());
        }
    }
}

#[test]
fn fd_recycling_returns_badfd_never_another_files_bytes() {
    // The ABA property of the generation-tagged slab fd table: one
    // thread close/reopens the same path in a loop — churning the freed
    // slot through a *decoy* file with different bytes so a recycled
    // slot really does belong to another file — while 4 reader threads
    // hammer whatever fd was last published. A stale-generation lookup
    // must come back as BadFd; it must never resolve to the decoy's
    // handle and return its bytes.
    const ROUNDS: usize = 2_000;

    let dir = tempdir("fd-recycle");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 16 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .build();
    let sea = SeaIo::mount_with(cfg, SeaLists::default(), |t| t).unwrap();
    let sea = &sea;

    let fd = sea.create("/target.dat").unwrap();
    sea.write(fd, &[0xAA; 4096]).unwrap();
    sea.close(fd).unwrap();
    let fd = sea.create("/decoy.dat").unwrap();
    sea.write(fd, &[0xBB; 4096]).unwrap();
    sea.close(fd).unwrap();

    let published = AtomicU64::new(0); // 0 = nothing published yet
    let stop = AtomicBool::new(false);
    let ok_reads = AtomicU64::new(0);
    let stale_hits = AtomicU64::new(0);
    let (published, stop) = (&published, &stop);
    let (ok_reads, stale_hits) = (&ok_reads, &stale_hits);

    std::thread::scope(|s| {
        s.spawn(move || {
            for _ in 0..ROUNDS {
                let fd = sea.open("/target.dat", OpenMode::Read).unwrap();
                published.store(fd, Ordering::Release);
                std::thread::yield_now();
                sea.close(fd).unwrap();
                // LIFO free list: this open recycles the slot the target
                // fd just vacated, with a bumped generation.
                let decoy = sea.open("/decoy.dat", OpenMode::Read).unwrap();
                sea.close(decoy).unwrap();
            }
            stop.store(true, Ordering::Release);
        });
        for _ in 0..4 {
            s.spawn(move || {
                let mut buf = [0u8; 256];
                while !stop.load(Ordering::Acquire) {
                    let fd = published.load(Ordering::Acquire);
                    if fd == 0 {
                        std::hint::spin_loop();
                        continue;
                    }
                    match sea.read(fd, &mut buf) {
                        Ok(n) => {
                            assert!(
                                buf[..n].iter().all(|&b| b == 0xAA),
                                "stale fd read another file's bytes"
                            );
                            ok_reads.fetch_add(1, Ordering::Relaxed);
                            // rewind for the next read; the fd may go
                            // stale between the read and the seek
                            let _ = sea.lseek(fd, std::io::SeekFrom::Start(0));
                        }
                        Err(SeaError::BadFd(_)) => {
                            stale_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("unexpected error on recycled fd: {e}"),
                    }
                    // let the opener's close/reopen win the per-fd mutex
                    std::thread::yield_now();
                }
            });
        }
    });

    assert!(
        ok_reads.load(Ordering::Relaxed) > 0,
        "readers never overlapped a live fd — the race never happened"
    );
    // stale lookups are expected under this schedule but not guaranteed;
    // correctness is the in-loop assertions (BadFd or target bytes, only)
    println!(
        "fd recycling: {} live reads, {} stale BadFd lookups over {ROUNDS} recycles",
        ok_reads.load(Ordering::Relaxed),
        stale_hits.load(Ordering::Relaxed)
    );
}

#[test]
fn cache_read_completes_during_throttled_persist_write() {
    // Persist tier throttled to 256 KiB/s: a 256 KiB write blocks its fd
    // inside the token bucket for roughly a second. Reads of a cached
    // file on another fd must not queue behind it.
    const BW: f64 = 256.0 * 1024.0;
    const BIG: usize = 256 * 1024;

    let dir = tempdir("throttle-regress");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * 1024)
        .persist("lustre", dir.subdir("lustre"), u64::MAX / 4)
        .build();
    let sea =
        SeaIo::mount_with(cfg, SeaLists::default(), |t| t.with_bandwidth_limit(BW)).unwrap();
    let sea = &sea;

    // A small, hot file resident in the cache tier.
    let fd = sea.create("/hot").unwrap();
    sea.write(fd, &[1u8; 1024]).unwrap();
    sea.close(fd).unwrap();
    assert_eq!(sea.stat("/hot").unwrap().tier, "tmpfs");

    let barrier = Barrier::new(2);
    let writer_done = AtomicBool::new(false);
    let barrier = &barrier;
    let writer_done = &writer_done;

    std::thread::scope(|s| {
        s.spawn(move || {
            // 256 KiB > the 64 KiB cache: the write spills to the
            // throttled persist tier and sits in Tier::wait_data there.
            let big = vec![2u8; BIG];
            let fd = sea.create("/big.dat").unwrap();
            barrier.wait();
            sea.write(fd, &big).unwrap();
            sea.close(fd).unwrap();
            writer_done.store(true, Ordering::Release);
        });

        barrier.wait();
        // Give the writer time to enter the throttle wait.
        std::thread::sleep(Duration::from_millis(50));

        let mut completed = 0u32;
        let mut max_read_ms = 0.0f64;
        while !writer_done.load(Ordering::Acquire) && completed < 10_000 {
            let t0 = Instant::now();
            let fd = sea.open("/hot", OpenMode::Read).unwrap();
            let mut buf = [0u8; 1024];
            let n = sea.read(fd, &mut buf).unwrap();
            sea.close(fd).unwrap();
            assert_eq!(n, 1024);
            max_read_ms = max_read_ms.max(t0.elapsed().as_secs_f64() * 1e3);
            completed += 1;
        }

        // The discriminating oracle is the count: with the old global
        // fd-table mutex the first read blocked until the writer released
        // it (~1 s), so essentially zero reads completed in the window.
        assert!(
            completed >= 50,
            "only {completed} cache reads finished while the persist write was throttled"
        );
        // Latency bound is diagnostics-grade and deliberately far above
        // scheduler jitter on shared CI runners, yet far below the ~1 s
        // stall the old global lock caused.
        assert!(
            max_read_ms < 750.0,
            "a cache read stalled {max_read_ms:.0} ms behind a throttled persist-tier write"
        );
    });

    // The big file really went through the throttled persist tier.
    assert_eq!(sea.stat("/big.dat").unwrap().tier, "lustre");
    assert_eq!(sea.stat("/big.dat").unwrap().size, BIG as u64);
}

#[test]
fn rename_racing_inflight_flush_never_strands_persist_copy() {
    // Seed-inherited window (ROADMAP): a rename racing an in-flight
    // flush copy could strand the flusher's persist copy at the
    // pre-rename path while the namespace recorded a replica at the new
    // one. The per-file fence must make the rename wait out or cancel
    // the transfer instead.
    const BW: f64 = 256.0 * 1024.0; // 256 KiB payload -> ~1 s in flight
    let dir = tempdir("fence-rename");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 8 * MIB)
        .persist("lustre", dir.subdir("lustre"), u64::MAX / 4)
        .flusher(false, 100)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    );
    let sea = SeaIo::mount_with(cfg, lists, |t| t.with_bandwidth_limit(BW)).unwrap();
    let sea = &sea;
    let payload = vec![5u8; 256 * 1024];
    let fd = sea.create("/d/a.out").unwrap();
    sea.write(fd, &payload).unwrap();
    sea.close(fd).unwrap();

    std::thread::scope(|s| {
        let flusher = s.spawn(move || flush_pass(sea.core(), false));
        // let the throttled flush copy get mid-flight
        std::thread::sleep(Duration::from_millis(100));
        sea.rename("/d/a.out", "/d/b.out").unwrap();
        let rep = flusher.join().unwrap();
        assert_eq!(rep.errors, 0, "{rep:?}");
    });

    let core = sea.core();
    let persist = core.tiers.persist();
    // Whichever side won the fence, the old path must be fully gone:
    // no stranded persist copy, no namespace entry, no temp litter.
    assert!(
        !persist.physical("/d/a.out").exists(),
        "persist copy stranded at the pre-rename path"
    );
    assert!(!core.ns.exists("/d/a.out"));
    assert!(core.ns.exists("/d/b.out"));
    assert_no_temp_litter(persist.root());
    assert_no_temp_litter(core.tiers.get(0).root());

    // The drain persists the renamed file, byte-for-byte.
    let rep = drain(core);
    assert_eq!(rep.errors, 0, "{rep:?}");
    let meta = core.ns.lookup("/d/b.out").unwrap();
    assert!(!meta.dirty(), "renamed file never reflushed");
    assert_eq!(
        std::fs::read(persist.physical("/d/b.out")).unwrap(),
        payload,
        "persist bytes corrupted by the racing rename"
    );
    assert_no_temp_litter(persist.root());
}

#[test]
fn unlink_recreate_racing_inflight_flush_keeps_incarnations_separate() {
    // Second seed-inherited window: a truncate/recreate racing an
    // in-flight flush of the old incarnation could interleave bytes of
    // the two incarnations on the persist tier. With fenced, atomic
    // (temp + rename) copies, the persisted file must be exactly one
    // incarnation's bytes — the final one's.
    const BW: f64 = 256.0 * 1024.0;
    let dir = tempdir("fence-recreate");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 8 * MIB)
        .persist("lustre", dir.subdir("lustre"), u64::MAX / 4)
        .flusher(false, 100)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    );
    let sea = SeaIo::mount_with(cfg, lists, |t| t.with_bandwidth_limit(BW)).unwrap();
    let sea = &sea;
    let v1 = vec![1u8; 256 * 1024];
    let v2 = vec![2u8; 96 * 1024];
    let fd = sea.create("/d/x.out").unwrap();
    sea.write(fd, &v1).unwrap();
    sea.close(fd).unwrap();

    std::thread::scope(|s| {
        let flusher = s.spawn(move || flush_pass(sea.core(), false));
        std::thread::sleep(Duration::from_millis(100));
        // unlink + recreate while the v1 flush copy is mid-flight
        sea.unlink("/d/x.out").unwrap();
        let fd = sea.create("/d/x.out").unwrap();
        sea.write(fd, &v2).unwrap();
        sea.close(fd).unwrap();
        let rep = flusher.join().unwrap();
        assert_eq!(rep.errors, 0, "{rep:?}");
    });

    let core = sea.core();
    let rep = drain(core);
    assert_eq!(rep.errors, 0, "{rep:?}");
    let on_persist = std::fs::read(core.tiers.persist().physical("/d/x.out")).unwrap();
    assert_eq!(
        on_persist, v2,
        "persist copy mixed bytes from two incarnations (len {})",
        on_persist.len()
    );
    assert!(!core.ns.lookup("/d/x.out").unwrap().dirty());
    assert_no_temp_litter(core.tiers.persist().root());
    assert_no_temp_litter(core.tiers.get(0).root());
}

#[test]
fn rename_while_open_keeps_write_tracking_under_new_name() {
    // The seed bug this PR pins: write() ignored record_write's false
    // return, so bytes written through an fd whose file was concurrently
    // renamed silently dropped their size/dirty update and were never
    // flushed under the new name. With the retired-record protocol the
    // update follows the rename — in both the already-dirty (fast-path)
    // and clean→dirty (transition) cases.
    let dir = tempdir("rename-open-write");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 8 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    );
    let sea = SeaIo::mount_with(cfg, lists, |t| t).unwrap();

    // Case 1: dirty file — the record travels with the rename; the
    // lock-free publish keeps landing on it.
    let fd = sea.create("/d/a.out").unwrap();
    sea.write(fd, &[1u8; 100]).unwrap();
    sea.rename("/d/a.out", "/d/b.out").unwrap();
    sea.write(fd, &[2u8; 100]).unwrap();
    sea.close(fd).unwrap();
    let meta = sea.core().ns.lookup("/d/b.out").unwrap();
    assert_eq!(meta.size(), 200, "post-rename write lost from tracking");
    assert_eq!(meta.open_count, 0, "renamed-then-closed fd left the file pinned");
    let rep = flush_pass(sea.core(), false);
    assert_eq!(rep.flushed, 1, "{rep:?}");
    let persist = sea.core().tiers.persist();
    let bytes = std::fs::read(persist.physical("/d/b.out")).unwrap();
    assert_eq!(bytes.len(), 200, "flush missed post-rename bytes");
    assert_eq!(&bytes[..100], &[1u8; 100][..]);
    assert_eq!(&bytes[100..], &[2u8; 100][..]);
    assert!(!persist.physical("/d/a.out").exists());

    // Case 2: clean file — the first write after the rename is a
    // clean→dirty transition through the retired record: it must
    // re-resolve, re-dirty, and re-queue under the new name (exactly
    // the update the seed dropped).
    let fd = sea.open("/d/b.out", OpenMode::ReadWrite).unwrap();
    sea.rename("/d/b.out", "/d/c.out").unwrap();
    sea.lseek(fd, std::io::SeekFrom::Start(200)).unwrap();
    sea.write(fd, &[3u8; 50]).unwrap();
    sea.close(fd).unwrap();
    let meta = sea.core().ns.lookup("/d/c.out").unwrap();
    assert!(meta.dirty(), "post-rename write must re-dirty the file");
    assert_eq!(meta.size(), 250);
    let rep = flush_pass(sea.core(), false);
    assert_eq!(rep.flushed, 1, "post-rename write never re-flushed: {rep:?}");
    let bytes = std::fs::read(persist.physical("/d/c.out")).unwrap();
    assert_eq!(bytes.len(), 250);
    assert_eq!(&bytes[200..], &[3u8; 50][..]);
}

#[test]
fn writes_across_concurrent_renames_all_flush_under_final_name() {
    // Stress form of the lost-write pin: one thread appends through a
    // long-lived fd while the main thread renames the file through a
    // chain of hops. Every written byte must be tracked and flushed
    // under the final post-rename path.
    const CHUNKS: usize = 200;
    const HOPS: usize = 20;

    let dir = tempdir("rename-write-race");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 8 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    );
    let sea = SeaIo::mount_with(cfg, lists, |t| t).unwrap();
    let sea = &sea;

    let fd = sea.create("/r/h0.out").unwrap();
    std::thread::scope(|s| {
        let writer = s.spawn(move || {
            for i in 0..CHUNKS {
                sea.write(fd, &[7u8; 1024]).unwrap();
                if i % 8 == 0 {
                    std::thread::yield_now();
                }
            }
        });
        for h in 0..HOPS {
            let from = format!("/r/h{h}.out");
            let to = format!("/r/h{}.out", h + 1);
            sea.rename(&from, &to).unwrap();
            std::thread::yield_now();
        }
        writer.join().unwrap();
    });
    sea.close(fd).unwrap();

    let final_name = format!("/r/h{HOPS}.out");
    let meta = sea.core().ns.lookup(&final_name).unwrap();
    assert_eq!(
        meta.size(),
        (CHUNKS * 1024) as u64,
        "writes across concurrent renames lost from tracking"
    );
    assert_eq!(meta.open_count, 0);
    let rep = drain(sea.core());
    assert_eq!(rep.errors, 0, "{rep:?}");
    let bytes =
        std::fs::read(sea.core().tiers.persist().physical(&final_name)).unwrap();
    assert_eq!(
        bytes.len(),
        CHUNKS * 1024,
        "persisted bytes incomplete under the post-rename path"
    );
    assert!(bytes.iter().all(|&b| b == 7), "persisted bytes corrupted");
    assert!(!sea.core().ns.lookup(&final_name).unwrap().dirty());
    assert_no_temp_litter(sea.core().tiers.persist().root());
}

#[test]
fn unlink_while_open_write_never_resurrects_the_path() {
    // The other half of the retired-record fix: a write through an fd
    // whose file was unlinked succeeds into the detached inode (POSIX)
    // but must not resurrect the namespace entry, must not leak its
    // reservation, and must be counted instead of silently dropped.
    let dir = tempdir("unlink-open-write");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 8 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    );
    let sea = SeaIo::mount_with(cfg, lists, |t| t).unwrap();

    let fd = sea.create("/u/gone.out").unwrap();
    sea.write(fd, &[1u8; 64]).unwrap();
    sea.unlink("/u/gone.out").unwrap();
    sea.write(fd, &[2u8; 64]).unwrap(); // into the detached inode: Ok
    sea.close(fd).unwrap();

    assert!(!sea.core().ns.exists("/u/gone.out"), "write resurrected the path");
    assert!(
        sea.stats().write_untracked >= 1,
        "untracked write must be counted, not ignored"
    );
    assert_eq!(
        sea.core().tiers.get(0).used(),
        0,
        "post-unlink write leaked its growth reservation"
    );
    let rep = drain(sea.core());
    assert_eq!(rep.flushed + rep.moved, 0, "unlinked file must not flush: {rep:?}");
    assert!(!sea.core().ns.exists("/u/gone.out"), "drain resurrected the path");
    assert!(!sea.core().tiers.persist().physical("/u/gone.out").exists());
}

#[test]
fn rename_storm_against_background_flusher_converges() {
    // Many rename hops racing a live flusher thread: every hop must end
    // with exactly one tracked file whose bytes are intact, and the
    // final drain persists the final name.
    let dir = tempdir("fence-storm");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 8 * MIB)
        .persist("lustre", dir.subdir("lustre"), u64::MAX / 4)
        .flusher(true, 1)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    );
    let sess = SeaSession::start(cfg, lists, |t| t.with_bandwidth_limit(2.0 * MIB as f64))
        .unwrap();
    let sea = sess.io();
    let payload = vec![9u8; 64 * 1024];
    let fd = sea.create("/hop/n0.out").unwrap();
    sea.write(fd, &payload).unwrap();
    sea.close(fd).unwrap();

    const HOPS: usize = 30;
    for i in 0..HOPS {
        let from = format!("/hop/n{i}.out");
        let to = format!("/hop/n{}.out", i + 1);
        sea.rename(&from, &to).unwrap();
        std::thread::sleep(Duration::from_millis(3));
    }
    let final_name = format!("/hop/n{HOPS}.out");
    let core = sess.io().core().clone();
    let (_stats, report) = sess.unmount();
    assert_eq!(report.errors, 0, "{report:?}");

    // exactly one logical file survives, at the final name, intact
    let paths: Vec<String> = core.ns.all_paths();
    assert_eq!(paths, vec![final_name.clone()], "stray entries: {paths:?}");
    let persist = core.tiers.persist();
    assert_eq!(std::fs::read(persist.physical(&final_name)).unwrap(), payload);
    // no intermediate hop left a stranded persist copy
    for i in 0..HOPS {
        assert!(
            !persist.physical(&format!("/hop/n{i}.out")).exists(),
            "stranded persist copy at hop {i}"
        );
    }
    assert_no_temp_litter(persist.root());
}

/// One run of the mixed-size eviction workload under `policy`: 8 threads
/// create hot 4 KiB files and cold 64 MiB volumes against an undersized
/// cache, a flush turns the survivors into clean dual-replica eviction
/// candidates, and one more 64 MiB create forces the eviction path.
/// Returns the scheduler counters, how many hot files kept their cache
/// replica, and whether the cached cold volume survived.
fn mixed_size_eviction_run(policy: &str) -> (sea::sched::SchedSnapshot, usize, bool) {
    const SMALL_THREADS: usize = 6;
    const SMALLS_PER_THREAD: usize = 8;
    const SMALL: usize = 4 * 1024;
    const BIG: usize = 64 * MIB as usize;

    let dir = tempdir("stress-gdsf");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 80 * MIB)
        .persist("lustre", dir.subdir("lustre"), u64::MAX / 4)
        .flusher(false, 100)
        .prefetcher(false)
        .promote_on_read(false)
        .readahead(0)
        .sched_policy(policy)
        .build();
    let sea = SeaIo::mount_with(cfg, SeaLists::flush_all(), |t| t).unwrap();
    let sea = &sea;

    // 8 threads, mixed sizes, all racing the undersized cache: six
    // create the hot 4 KiB set, two create one 64 MiB volume each (the
    // cache can hold only one of the two — the loser falls through to
    // the persist tier, which is itself part of the stress).
    let barrier = Barrier::new(SMALL_THREADS + 2);
    std::thread::scope(|s| {
        for w in 0..SMALL_THREADS {
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                for i in 0..SMALLS_PER_THREAD {
                    let p = format!("/hot/w{w}-{i}.out");
                    let fd = sea.create(&p).unwrap();
                    sea.write(fd, &vec![1u8; SMALL]).unwrap();
                    sea.close(fd).unwrap();
                }
            });
        }
        for b in 0..2 {
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                let p = format!("/cold/big{b}.out");
                let fd = sea.create(&p).unwrap();
                sea.write(fd, &vec![2u8; BIG]).unwrap();
                sea.close(fd).unwrap();
            });
        }
    });

    // Drain everything dirty: the cache residents become clean
    // dual-replica (cache + persist) eviction candidates.
    let core = sea.core();
    let rep = flush_pass(core, false);
    assert_eq!(rep.errors, 0, "{rep:?}");

    // Hammer the hot set: high access frequency, but *older* access
    // stamps than the cold volume's final touch below — exactly the
    // shape where recency (LRU) and cost (GDSF) disagree.
    for _ in 0..8 {
        for w in 0..SMALL_THREADS {
            for i in 0..SMALLS_PER_THREAD {
                let fd = sea.open(&format!("/hot/w{w}-{i}.out"), OpenMode::Read).unwrap();
                sea.close(fd).unwrap();
            }
        }
    }
    let cached_big = (0..2)
        .map(|b| format!("/cold/big{b}.out"))
        .find(|p| core.ns.with_meta(p, |m| m.has_replica(0)).unwrap())
        .expect("one cold volume must have won the cache");
    let fd = sea.open(&cached_big, OpenMode::Read).unwrap();
    sea.close(fd).unwrap();

    // Pressure: one more 64 MiB create only fits by evicting.
    let fd = sea.create("/probe.out").unwrap();
    sea.write(fd, &vec![3u8; BIG]).unwrap();
    sea.close(fd).unwrap();

    let hot_survivors = (0..SMALL_THREADS)
        .flat_map(|w| (0..SMALLS_PER_THREAD).map(move |i| format!("/hot/w{w}-{i}.out")))
        .filter(|p| core.ns.with_meta(p, |m| m.has_replica(0)).unwrap())
        .count();
    let big_survived = core.ns.with_meta(&cached_big, |m| m.has_replica(0)).unwrap();
    (core.sched.snapshot(), hot_survivors, big_survived)
}

#[test]
fn writers_survive_a_flapping_cache_tier_with_zero_lost_bytes() {
    // PR-9 degraded-mode stress: 8 writer threads run a create/write/
    // read-back/close loop while (a) the cache tier's breaker flag flaps
    // down 50 ms / up 50 ms and (b) a 5% flaky fault injects EIO into
    // every transfer touching it. The health engine must absorb all of
    // it: not one error surfaces to an application call, not one byte is
    // lost, and the retry / failover counters prove the degraded paths
    // actually ran rather than the storm missing the windows.
    //
    // The cache tier is named `fast` on purpose: the CI chaos job runs
    // this whole suite under `SEA_FAULTS=tier.fast=flaky:0.05`, which
    // targets exactly this test's tier and stays inert for the others.
    const WORKERS: usize = 8;
    const ITERS: usize = 40;

    use sea::health::TierState;

    let dir = tempdir("stress-flap");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("fast", dir.subdir("fast"), 64 * MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(true, 5)
        .health_probe_interval(50)
        .faults("tier.fast=flaky:0.05")
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::empty(),
        PathRules::empty(),
    );
    let sess = SeaSession::start(cfg, lists, |t| t).unwrap();
    let sea = sess.io();
    let core = sea.core().clone();

    let stop_flapping = AtomicBool::new(false);
    let stop_flapping = &stop_flapping;
    {
        let core = &core;
        std::thread::scope(|s| {
            // The flapper: breaker flag down 50 ms, up 50 ms, in a loop.
            // While down, flush copies fail with "tier fast is down",
            // the classifier trips the health breaker, and new placements
            // re-route to the persist tier.
            s.spawn(move || {
                while !stop_flapping.load(Ordering::Acquire) {
                    core.tiers.get(0).set_down(true);
                    std::thread::sleep(Duration::from_millis(50));
                    core.tiers.get(0).set_down(false);
                    std::thread::sleep(Duration::from_millis(50));
                }
            });
            for w in 0..WORKERS {
                s.spawn(move || {
                    for i in 0..ITERS {
                        let keep = format!("/w{w}/r{i}.out");
                        let payload = format!("data-{w}-{i}");
                        let fd = sea.create(&keep).unwrap();
                        sea.write(fd, payload.as_bytes()).unwrap();
                        sea.close(fd).unwrap();
                        let fd = sea.open(&keep, OpenMode::Read).unwrap();
                        let mut buf = [0u8; 32];
                        let n = sea.read(fd, &mut buf).unwrap();
                        assert_eq!(&buf[..n], payload.as_bytes());
                        sea.close(fd).unwrap();
                        if i % 4 == 0 {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                    }
                });
            }
        });
        stop_flapping.store(true, Ordering::Release);
    }
    core.tiers.get(0).set_down(false);

    // Deterministic failover (the storm makes one overwhelmingly likely,
    // but the counter assertion must not be schedule-dependent): force
    // the breaker open and read a cache-resident file through it.
    let deadline = Instant::now() + Duration::from_secs(10);
    while core.health.state(0) != TierState::Up {
        assert!(Instant::now() < deadline, "prober never re-admitted `fast`");
        std::thread::sleep(Duration::from_millis(10));
    }
    let fd = sea.create("/sentinel.out").unwrap();
    sea.write(fd, b"sentinel").unwrap();
    sea.close(fd).unwrap();
    assert_eq!(
        sea.stat("/sentinel.out").unwrap().tier,
        "fast",
        "healthy cache with room must take the sentinel"
    );
    // Hold the breaker flag down so the 50 ms prober's probe is vetoed
    // and cannot re-admit the tier between the forced error and the open.
    core.tiers.get(0).set_down(true);
    core.health
        .note_error(0, &std::io::Error::other("tier fast is down"));
    assert_ne!(core.health.state(0), TierState::Up);
    let fd = sea.open("/sentinel.out", OpenMode::Read).unwrap();
    let mut buf = [0u8; 8];
    let n = sea.read(fd, &mut buf).unwrap();
    assert_eq!(&buf[..n], b"sentinel");
    sea.close(fd).unwrap();
    core.tiers.get(0).set_down(false);

    // Same seatbelt for the retry counter: one transient error that heals
    // on the second attempt must be retried (and counted) by the engine,
    // whatever the storm's flaky rolls happened to hit.
    let healed = AtomicBool::new(false);
    core.health
        .with_retry(0, || {
            if healed.swap(true, Ordering::AcqRel) {
                Ok(())
            } else {
                Err(std::io::Error::other("injected flaky EIO at tier.fast"))
            }
        })
        .unwrap();

    // Let the prober close the breaker again, then converge: forced
    // passes retry through the residual 5% flaky errors until nothing
    // flush-listed is dirty (unmount's drain stops early on errors, so
    // the test owns convergence).
    let deadline = Instant::now() + Duration::from_secs(30);
    while core.health.state(0) != TierState::Up {
        assert!(Instant::now() < deadline, "prober never closed the breaker");
        std::thread::sleep(Duration::from_millis(10));
    }
    while !core.ns.dirty_files().is_empty() {
        assert!(
            Instant::now() < deadline,
            "flush never converged: {:?} still dirty",
            core.ns.dirty_files().len()
        );
        flush_pass(&core, true);
        std::thread::sleep(Duration::from_millis(5));
    }

    let retries = core.health.retries();
    let failovers = core.health.failovers();
    let (_stats, _report) = sess.unmount();

    // Zero lost bytes: every file the workers wrote is durable on the
    // persist tier with exactly its payload.
    let persist = core.tiers.persist();
    for w in 0..WORKERS {
        for i in 0..ITERS {
            let keep = format!("/w{w}/r{i}.out");
            let want = format!("data-{w}-{i}");
            let got = std::fs::read(persist.physical(&keep))
                .unwrap_or_else(|e| panic!("{keep} lost from persist: {e}"));
            assert_eq!(got, want.as_bytes(), "{keep} corrupted on persist");
        }
    }
    assert_no_temp_litter(persist.root());
    assert_no_temp_litter(core.tiers.get(0).root());

    // The degraded paths really ran: transient retries from the flaky
    // fault, and at least the forced failover above.
    assert!(retries > 0, "flaky fault never produced a counted retry");
    assert!(failovers > 0, "no read ever failed over off the flapping tier");
}

#[test]
fn gdsf_beats_lru_on_refetch_cost_for_mixed_sizes() {
    const HOT_FILES: usize = 48; // 6 threads × 8 files

    let (gdsf, gdsf_hot, gdsf_big) = mixed_size_eviction_run("gdsf");
    let (lru, lru_hot, lru_big) = mixed_size_eviction_run("lru");

    // GDSF drains the one cheap-per-byte cold volume and keeps the whole
    // hammered hot set resident.
    assert!(gdsf.evictions >= 1, "{gdsf:?}");
    assert!(!gdsf_big, "gdsf must evict the cold 64 MiB volume");
    assert_eq!(gdsf_hot, HOT_FILES, "gdsf must keep the hot set cached");

    // The old ordering: LRU walks oldest-stamp-first, so it ages out the
    // entire hot set (older stamps) before reaching the cold volume it
    // must evict anyway.
    assert!(!lru_big, "lru also frees the big volume in the end");
    assert_eq!(lru_hot, 0, "lru must reproduce oldest-first eviction");
    assert_eq!(
        lru.evictions,
        gdsf.evictions + HOT_FILES as u64,
        "lru pays {HOT_FILES} extra evictions for the same demand"
    );

    // The headline: same staging demand, strictly lower aggregate
    // re-fetch cost under GDSF.
    assert!(
        gdsf.refetch_cost < lru.refetch_cost,
        "gdsf {gdsf:?} must charge strictly less than lru {lru:?}"
    );
}
