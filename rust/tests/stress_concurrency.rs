//! Concurrency stress + regression tests for the lock-sharded hot path.
//!
//! The interceptor used to serialise every call on one global fd-table
//! mutex held *across* physical I/O and throttle sleeps. These tests pin
//! the two properties the sharded design must provide:
//!
//! 1. N threads doing create/write/read/close through one mount keep the
//!    tier-reservation and call-counter invariants intact while the
//!    background flusher runs;
//! 2. a cache-tier read completes promptly while a throttled persist-tier
//!    write is mid-flight on another fd (the regression the old global
//!    lock caused: every worker stalled behind one throttled write).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Barrier;
use std::time::{Duration, Instant};

use sea::config::SeaConfig;
use sea::flusher::SeaSession;
use sea::intercept::{OpenMode, SeaIo};
use sea::pathrules::{PathRules, SeaLists};
use sea::testing::tempdir::tempdir;
use sea::util::MIB;

#[test]
fn stress_invariants_hold_under_concurrent_io_with_flusher() {
    const WORKERS: usize = 8;
    const ITERS: usize = 50;

    let dir = tempdir("stress-conc");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), MIB)
        .persist("lustre", dir.subdir("lustre"), 100_000 * MIB)
        .flusher(true, 5)
        .build();
    let lists = SeaLists::new(
        PathRules::parse(r".*\.out$").unwrap(),
        PathRules::parse(r".*\.tmp$").unwrap(),
        PathRules::empty(),
    );
    let sess = SeaSession::start(cfg, lists, |t| t).unwrap();
    let sea = sess.io();

    std::thread::scope(|s| {
        for w in 0..WORKERS {
            s.spawn(move || {
                for i in 0..ITERS {
                    let keep = format!("/w{w}/r{i}.out");
                    let fd = sea.create(&keep).unwrap();
                    sea.write(fd, format!("data-{w}-{i}").as_bytes()).unwrap();
                    sea.close(fd).unwrap();

                    let tmp = format!("/w{w}/s{i}.tmp");
                    let fd = sea.create(&tmp).unwrap();
                    sea.write(fd, &[w as u8; 512]).unwrap();
                    sea.close(fd).unwrap();
                    if i % 2 == 0 {
                        sea.unlink(&tmp).unwrap();
                    }

                    let fd = sea.open(&keep, OpenMode::Read).unwrap();
                    let mut buf = [0u8; 32];
                    let n = sea.read(fd, &mut buf).unwrap();
                    assert_eq!(&buf[..n], format!("data-{w}-{i}").as_bytes());
                    sea.close(fd).unwrap();
                }
            });
        }
    });

    let stats = sea.stats();
    assert_eq!(stats.create as usize, WORKERS * ITERS * 2);
    assert_eq!(stats.unlink as usize, WORKERS * ITERS / 2);
    assert_eq!(stats.write as usize, WORKERS * ITERS * 2);

    let core = sess.io().core().clone();
    let (_stats, report) = sess.unmount();
    assert_eq!(report.errors, 0, "no flush may fail: {report:?}");

    // Every .out file was persisted by the drain and survives in the
    // namespace; every .tmp file is gone (unlinked or drain-evicted).
    let persist_idx = core.tiers.persist_idx();
    for w in 0..WORKERS {
        for i in 0..ITERS {
            let keep = format!("/w{w}/r{i}.out");
            let on_disk = core.tiers.persist().physical(&keep);
            assert!(on_disk.exists(), "{keep} missing on persist tier");
            assert!(
                core.ns.with_meta(&keep, |m| m.has_replica(persist_idx)).unwrap(),
                "{keep} lacks persist replica"
            );
        }
    }
    for path in core.ns.all_paths() {
        assert!(!path.ends_with(".tmp"), "{path} survived drain");
    }

    // Reservation invariant: each cache tier's accounted usage equals the
    // total size of replicas the namespace still records there.
    for tier_idx in 0..core.tiers.persist_idx() {
        let mut expected = 0u64;
        for path in core.ns.all_paths() {
            core.ns.with_meta(&path, |m| {
                if m.has_replica(tier_idx) {
                    expected += m.size;
                }
            });
        }
        assert_eq!(
            core.tiers.get(tier_idx).used(),
            expected,
            "tier {tier_idx} reservation drifted from namespace contents"
        );
    }
}

#[test]
fn cache_read_completes_during_throttled_persist_write() {
    // Persist tier throttled to 256 KiB/s: a 256 KiB write blocks its fd
    // inside the token bucket for roughly a second. Reads of a cached
    // file on another fd must not queue behind it.
    const BW: f64 = 256.0 * 1024.0;
    const BIG: usize = 256 * 1024;

    let dir = tempdir("throttle-regress");
    let cfg = SeaConfig::builder(dir.subdir("mount"))
        .cache("tmpfs", dir.subdir("tmpfs"), 64 * 1024)
        .persist("lustre", dir.subdir("lustre"), u64::MAX / 4)
        .build();
    let sea =
        SeaIo::mount_with(cfg, SeaLists::default(), |t| t.with_bandwidth_limit(BW)).unwrap();
    let sea = &sea;

    // A small, hot file resident in the cache tier.
    let fd = sea.create("/hot").unwrap();
    sea.write(fd, &[1u8; 1024]).unwrap();
    sea.close(fd).unwrap();
    assert_eq!(sea.stat("/hot").unwrap().tier, "tmpfs");

    let barrier = Barrier::new(2);
    let writer_done = AtomicBool::new(false);
    let barrier = &barrier;
    let writer_done = &writer_done;

    std::thread::scope(|s| {
        s.spawn(move || {
            // 256 KiB > the 64 KiB cache: the write spills to the
            // throttled persist tier and sits in Tier::wait_data there.
            let big = vec![2u8; BIG];
            let fd = sea.create("/big.dat").unwrap();
            barrier.wait();
            sea.write(fd, &big).unwrap();
            sea.close(fd).unwrap();
            writer_done.store(true, Ordering::Release);
        });

        barrier.wait();
        // Give the writer time to enter the throttle wait.
        std::thread::sleep(Duration::from_millis(50));

        let mut completed = 0u32;
        let mut max_read_ms = 0.0f64;
        while !writer_done.load(Ordering::Acquire) && completed < 10_000 {
            let t0 = Instant::now();
            let fd = sea.open("/hot", OpenMode::Read).unwrap();
            let mut buf = [0u8; 1024];
            let n = sea.read(fd, &mut buf).unwrap();
            sea.close(fd).unwrap();
            assert_eq!(n, 1024);
            max_read_ms = max_read_ms.max(t0.elapsed().as_secs_f64() * 1e3);
            completed += 1;
        }

        // The discriminating oracle is the count: with the old global
        // fd-table mutex the first read blocked until the writer released
        // it (~1 s), so essentially zero reads completed in the window.
        assert!(
            completed >= 50,
            "only {completed} cache reads finished while the persist write was throttled"
        );
        // Latency bound is diagnostics-grade and deliberately far above
        // scheduler jitter on shared CI runners, yet far below the ~1 s
        // stall the old global lock caused.
        assert!(
            max_read_ms < 750.0,
            "a cache read stalled {max_read_ms:.0} ms behind a throttled persist-tier write"
        );
    });

    // The big file really went through the throttled persist tier.
    assert_eq!(sea.stat("/big.dat").unwrap().tier, "lustre");
    assert_eq!(sea.stat("/big.dat").unwrap().size, BIG as u64);
}
